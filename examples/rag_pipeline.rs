//! End-to-end RAG pipeline — the full three-layer stack on a real small
//! workload (the mandated E2E driver; results recorded in EXPERIMENTS.md).
//!
//! Flow:
//!   1. Boot the PJRT engine and load the AOT-compiled embedding encoder
//!      (Layer 1 Pallas attention + Layer 2 JAX model, lowered by
//!      `make artifacts`), wrapped in the dynamic micro-batcher.
//!   2. Start the Valori node (Layer 3): HTTP API + WAL + deterministic
//!      Q16.16 HNSW kernel.
//!   3. Ingest a synthetic multi-topic corpus *as text* over HTTP — each
//!      document is embedded in-process by the batcher, quantized at the
//!      kernel boundary, and indexed.
//!   4. Serve concurrent text queries; check retrieved documents share the
//!      query's topic; report throughput/latency and the state hash.
//!
//! Run: `make artifacts && cargo run --release --example rag_pipeline`

use std::sync::Arc;
use std::time::{Duration, Instant};
use valori::corpus::CorpusGen;
use valori::http::client;
use valori::json::{parse, Json};
use valori::node::{serve, EmbedBatcher, NodeConfig, NodeState};
use valori::runtime::{artifacts_available, artifacts_dir, embedder::Env, Embedder, Engine};
use valori::state::{Kernel, KernelConfig};

const N_DOCS: usize = 256;
const N_QUERIES: usize = 64;
const K: usize = 5;

fn main() {
    if !artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- Layer 1+2: AOT embedder behind the batcher -------------------
    let batcher = EmbedBatcher::start(
        || {
            let engine = Engine::cpu()?;
            println!("PJRT platform: {}", engine.platform());
            Embedder::load(&engine, artifacts_dir(), Env::A)
        },
        Duration::from_millis(2),
    )
    .expect("embedder");

    // ---- Layer 3: the node ---------------------------------------------
    let wal_path = std::env::temp_dir().join(format!("valori_rag_{}.wal", std::process::id()));
    let kernel = Kernel::new(KernelConfig::default_q16(128));
    let config = NodeConfig { workers: 8, wal_path: Some(wal_path.clone()) };
    let state = Arc::new(NodeState::new(kernel, &config, Some(batcher.handle())).unwrap());
    let server = serve(Arc::clone(&state), "127.0.0.1:0", config.workers).unwrap();
    let addr = server.addr();
    println!("valori node on http://{addr}");

    // ---- Ingest corpus as text over HTTP --------------------------------
    let mut gen = CorpusGen::new(7);
    let docs = gen.docs(N_DOCS);
    let t0 = Instant::now();
    let threads: Vec<_> = docs
        .chunks((N_DOCS / 8).max(1))
        .map(|chunk| {
            let chunk = chunk.to_vec();
            std::thread::spawn(move || {
                for d in chunk {
                    let body = Json::object(vec![
                        ("id", Json::Int(d.id as i64)),
                        ("text", Json::str(d.text.clone())),
                    ]);
                    let (status, resp) =
                        client::post_json(&addr, "/v1/insert", &body).expect("insert");
                    assert_eq!(status, 200, "insert failed: {resp}");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let ingest_s = t0.elapsed().as_secs_f64();
    println!(
        "ingested {N_DOCS} text documents in {ingest_s:.2}s ({:.1} docs/s, embed+quantize+index)",
        N_DOCS as f64 / ingest_s
    );

    // ---- Query: concurrent text searches --------------------------------
    let queries: Vec<(usize, String)> =
        (0..N_QUERIES).map(|i| (i % CorpusGen::n_topics(), gen.query_for_topic(i))).collect();
    let topic_of: std::collections::HashMap<u64, usize> =
        docs.iter().map(|d| (d.id, d.topic)).collect();

    let t0 = Instant::now();
    let mut topic_hits = 0usize;
    let mut total_hits = 0usize;
    let mut latencies = Vec::with_capacity(N_QUERIES);
    for (topic, qtext) in &queries {
        let body = Json::object(vec![
            ("text", Json::str(qtext.clone())),
            ("k", Json::Int(K as i64)),
        ]);
        let tq = Instant::now();
        let (status, resp) = client::post_json(&addr, "/v1/query", &body).expect("query");
        latencies.push(tq.elapsed().as_secs_f64() * 1e3);
        assert_eq!(status, 200, "query failed: {resp}");
        for hit in resp.get("hits").as_array().unwrap() {
            let id = hit.get("id").as_u64().unwrap();
            total_hits += 1;
            if topic_of.get(&id) == Some(topic) {
                topic_hits += 1;
            }
        }
    }
    let query_s = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    let topic_precision = topic_hits as f64 / total_hits as f64;
    println!(
        "{N_QUERIES} text queries in {query_s:.2}s ({:.1} q/s) | p50 {p50:.1} ms p99 {p99:.1} ms \
         (includes embedding)",
        N_QUERIES as f64 / query_s
    );
    println!(
        "topic precision@{K}: {topic_precision:.3} (fraction of retrieved docs sharing the \
         query's topic; 5 topics -> random = 0.2)"
    );
    assert!(topic_precision > 0.5, "retrieval quality collapsed: {topic_precision}");

    // ---- Determinism spot-checks ----------------------------------------
    let (_, hash) = client::get_json(&addr, "/v1/hash").unwrap();
    println!("state hash: fnv={} ", hash.get("fnv").as_str().unwrap());

    // Replay the WAL offline and verify it reproduces the state hash.
    let rec = valori::wal::recover(&wal_path).expect("wal recover");
    let mut replayed = Kernel::new(KernelConfig::default_q16(128));
    valori::wal::replay(&mut replayed, &rec.entries).expect("replay");
    let replay_hash = format!("{:016x}", replayed.state_hash());
    assert_eq!(replay_hash, hash.get("fnv").as_str().unwrap(), "WAL replay diverged!");
    println!("WAL replay of {} commands reproduced the exact state hash", rec.entries.len());

    let (_, stats) = client::get_json(&addr, "/v1/stats").unwrap();
    println!("node stats: {stats}");

    server.stop();
    std::fs::remove_file(&wal_path).ok();
    println!("rag_pipeline OK");
}
