//! Deterministic binary Merkle tree over per-slot digests.
//!
//! Shape rule: the leaf layer always holds `capacity = next_pow2(slots)`
//! leaves; slots beyond the arena hash the fixed empty-slot sentinel
//! (`0x00`). Because capacity is a pure function of the slot count and the
//! slot count is a pure function of the command log, two replicas that
//! applied the same log have bit-identical trees — no balancing decisions,
//! no insertion-order sensitivity.
//!
//! Domain separation (second-preimage hardening): leaves hash as
//! `SHA256(0x00 ‖ encoding)`, internal nodes as `SHA256(0x01 ‖ L ‖ R)`, and
//! the cross-shard combined root as `SHA256(0x02 ‖ n ‖ roots…)` — a leaf
//! encoding can never be confused with a node pair.
//!
//! Updates are incremental: [`MerkleTree::set_leaf`] recomputes exactly the
//! `log2(capacity)` internal nodes on the slot's root path. Capacity growth
//! doubles the leaf layer and rebuilds from the *cached leaf hashes*
//! (amortized O(1) per insert, and it never re-reads record bytes).

#![forbid(unsafe_code)]

use crate::hash::sha256;

/// Domain tag for leaf hashes.
pub const LEAF_DOMAIN: u8 = 0x00;
/// Domain tag for internal-node hashes.
pub const NODE_DOMAIN: u8 = 0x01;
/// Domain tag for the cross-shard combined root fold.
pub const ROOT_DOMAIN: u8 = 0x02;

/// Canonical encoding of a never-used slot (single sentinel byte).
pub const EMPTY_SLOT_ENCODING: [u8; 1] = [0x00];

/// `SHA256(0x00 ‖ encoding)` — digest of one slot's canonical encoding.
pub fn leaf_hash(encoding: &[u8]) -> [u8; 32] {
    let mut buf = Vec::with_capacity(1 + encoding.len());
    buf.push(LEAF_DOMAIN);
    buf.extend_from_slice(encoding);
    sha256(&buf)
}

/// `SHA256(0x01 ‖ left ‖ right)` — internal node over two children.
pub fn node_hash(left: &[u8; 32], right: &[u8; 32]) -> [u8; 32] {
    let mut buf = [0u8; 65];
    buf[0] = NODE_DOMAIN;
    buf[1..33].copy_from_slice(left);
    buf[33..65].copy_from_slice(right);
    sha256(&buf)
}

/// `SHA256(0x02 ‖ n_shards ‖ root_0 ‖ …)` — the collection-level root over
/// per-shard Merkle roots (the Merkle analogue of
/// [`crate::state::sharded::root_hash_of`]).
pub fn combined_root(shard_roots: &[[u8; 32]]) -> [u8; 32] {
    let mut buf = Vec::with_capacity(5 + shard_roots.len() * 32);
    buf.push(ROOT_DOMAIN);
    buf.extend_from_slice(&(shard_roots.len() as u32).to_le_bytes());
    for r in shard_roots {
        buf.extend_from_slice(r);
    }
    sha256(&buf)
}

/// Recompute a shard root from a leaf encoding, its slot, and a sibling
/// path (one digest per level, bottom-up). This is the offline side of a
/// membership proof: no tree, no state, just `path.len()` hashes.
pub fn fold_path(leaf_encoding: &[u8], slot: usize, path: &[[u8; 32]]) -> [u8; 32] {
    let mut h = leaf_hash(leaf_encoding);
    let mut idx = slot;
    for sib in path {
        h = if idx % 2 == 0 { node_hash(&h, sib) } else { node_hash(sib, &h) };
        idx /= 2;
    }
    h
}

/// Incrementally-maintained Merkle tree. `levels[0]` is the leaf-hash
/// layer (length = capacity, a power of two); `levels.last()` is `[root]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MerkleTree {
    levels: Vec<Vec<[u8; 32]>>,
    /// `empties[l]` = root of an all-empty subtree of height `l`
    /// (precomputed so growth and padding never rehash sentinel bytes).
    empties: Vec<[u8; 32]>,
}

impl Default for MerkleTree {
    fn default() -> Self {
        Self::new()
    }
}

impl MerkleTree {
    /// Empty tree: capacity 1, root = hash of the empty-slot sentinel.
    pub fn new() -> Self {
        let e0 = leaf_hash(&EMPTY_SLOT_ENCODING);
        Self { levels: vec![vec![e0]], empties: vec![e0] }
    }

    /// Leaf-layer width (always a power of two, ≥ 1).
    pub fn capacity(&self) -> usize {
        self.levels[0].len()
    }

    /// Number of internal levels above the leaves = `log2(capacity)`.
    pub fn depth(&self) -> usize {
        self.levels.len() - 1
    }

    /// Current root digest.
    pub fn root(&self) -> [u8; 32] {
        self.levels[self.levels.len() - 1][0]
    }

    /// Set slot `slot` to the digest of `encoding`, growing capacity if
    /// needed, and recompute the O(log n) root path.
    pub fn set_leaf(&mut self, slot: usize, encoding: &[u8]) {
        self.set_leaf_hash(slot, leaf_hash(encoding));
    }

    fn set_leaf_hash(&mut self, slot: usize, h: [u8; 32]) {
        self.ensure_capacity(slot + 1);
        self.levels[0][slot] = h;
        let mut idx = slot;
        for l in 0..self.levels.len() - 1 {
            idx /= 2;
            let combined = node_hash(&self.levels[l][idx * 2], &self.levels[l][idx * 2 + 1]);
            self.levels[l + 1][idx] = combined;
        }
    }

    /// Grow the leaf layer to `next_pow2(n)` and rebuild the internal
    /// levels from the cached leaf hashes. Doubling keeps this amortized
    /// O(1) per insert.
    fn ensure_capacity(&mut self, n: usize) {
        if n <= self.levels[0].len() {
            return;
        }
        let new_cap = n.next_power_of_two();
        let depth = new_cap.trailing_zeros() as usize;
        while self.empties.len() <= depth {
            let last = self.empties[self.empties.len() - 1];
            self.empties.push(node_hash(&last, &last));
        }
        let mut leaves = std::mem::take(&mut self.levels[0]);
        leaves.resize(new_cap, self.empties[0]);
        let mut levels = vec![leaves];
        for l in 0..depth {
            let mut above = Vec::with_capacity(levels[l].len() / 2);
            for pair in levels[l].chunks_exact(2) {
                above.push(node_hash(&pair[0], &pair[1]));
            }
            levels.push(above);
        }
        self.levels = levels;
    }

    /// Digest stored at `(level, index)`; `None` out of range. Level 0 is
    /// the leaf layer.
    pub fn hash_at(&self, level: usize, index: usize) -> Option<[u8; 32]> {
        self.levels.get(level)?.get(index).copied()
    }

    /// Contiguous digests `[from, from+count)` at `level`; `None` if any
    /// index is out of range. This is the bisection wire for Merkle-diff
    /// repair ([`crate::replication`]).
    pub fn level_hashes(&self, level: usize, from: usize, count: usize) -> Option<&[[u8; 32]]> {
        let row = self.levels.get(level)?;
        let end = from.checked_add(count)?;
        row.get(from..end)
    }

    /// Sibling path for `slot`, bottom-up (one digest per level). Folded
    /// with [`fold_path`] it reproduces [`Self::root`]. `None` if `slot`
    /// is beyond capacity.
    pub fn proof_path(&self, slot: usize) -> Option<Vec<[u8; 32]>> {
        if slot >= self.capacity() {
            return None;
        }
        let mut path = Vec::with_capacity(self.depth());
        let mut idx = slot;
        for l in 0..self.depth() {
            path.push(self.levels[l][idx ^ 1]);
            idx /= 2;
        }
        Some(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_tree_root_is_sentinel_leaf() {
        let t = MerkleTree::new();
        assert_eq!(t.capacity(), 1);
        assert_eq!(t.depth(), 0);
        assert_eq!(t.root(), leaf_hash(&EMPTY_SLOT_ENCODING));
        assert_eq!(t.proof_path(0), Some(vec![]));
        assert_eq!(t.proof_path(1), None);
    }

    #[test]
    fn incremental_matches_scratch_rebuild() {
        // Apply leaves one by one to tree A; build tree B from scratch in
        // a different order. Roots must agree at every prefix of A.
        let encodings: Vec<Vec<u8>> =
            (0..13u8).map(|i| vec![i, i.wrapping_mul(7), 0xab]).collect();
        let mut a = MerkleTree::new();
        for (slot, enc) in encodings.iter().enumerate() {
            a.set_leaf(slot, enc);
            let mut b = MerkleTree::new();
            for (s2, e2) in encodings.iter().enumerate().take(slot + 1).rev() {
                b.set_leaf(s2, e2);
            }
            assert_eq!(a.root(), b.root(), "prefix {}", slot + 1);
        }
        assert_eq!(a.capacity(), 16);
        assert_eq!(a.depth(), 4);
    }

    #[test]
    fn growth_preserves_existing_leaves() {
        let mut t = MerkleTree::new();
        t.set_leaf(0, b"first");
        let h0 = t.hash_at(0, 0).unwrap();
        t.set_leaf(9, b"tenth"); // forces capacity 1 -> 16
        assert_eq!(t.capacity(), 16);
        assert_eq!(t.hash_at(0, 0), Some(h0));
        assert_eq!(t.hash_at(0, 3), Some(leaf_hash(&EMPTY_SLOT_ENCODING)));
    }

    #[test]
    fn proof_path_folds_to_root() {
        let mut t = MerkleTree::new();
        for slot in 0..6usize {
            t.set_leaf(slot, &[slot as u8; 5]);
        }
        for slot in 0..t.capacity() {
            let path = t.proof_path(slot).unwrap();
            assert_eq!(path.len(), t.depth());
            let enc: Vec<u8> = if slot < 6 {
                vec![slot as u8; 5]
            } else {
                EMPTY_SLOT_ENCODING.to_vec()
            };
            assert_eq!(fold_path(&enc, slot, &path), t.root());
        }
    }

    #[test]
    fn tampered_path_or_leaf_changes_root() {
        let mut t = MerkleTree::new();
        for slot in 0..4usize {
            t.set_leaf(slot, &[slot as u8, 0x55]);
        }
        let mut path = t.proof_path(2).unwrap();
        assert_eq!(fold_path(&[2, 0x55], 2, &path), t.root());
        // single-bit tamper in the leaf
        assert_ne!(fold_path(&[2, 0x54], 2, &path), t.root());
        // single-bit tamper in a sibling digest
        path[0][0] ^= 1;
        assert_ne!(fold_path(&[2, 0x55], 2, &path), t.root());
        // wrong slot index (changes fold orientation)
        assert_ne!(fold_path(&[2, 0x55], 3, &t.proof_path(2).unwrap()), t.root());
    }

    #[test]
    fn level_hashes_ranges() {
        let mut t = MerkleTree::new();
        for slot in 0..8usize {
            t.set_leaf(slot, &[slot as u8]);
        }
        assert_eq!(t.level_hashes(0, 0, 8).unwrap().len(), 8);
        assert_eq!(t.level_hashes(1, 2, 2).unwrap().len(), 2);
        assert_eq!(t.level_hashes(3, 0, 1).unwrap()[0], t.root());
        assert!(t.level_hashes(0, 7, 2).is_none());
        assert!(t.level_hashes(4, 0, 1).is_none());
        // children at level l fold into level l+1
        let kids = t.level_hashes(0, 4, 2).unwrap();
        assert_eq!(node_hash(&kids[0], &kids[1]), t.hash_at(1, 2).unwrap());
    }

    #[test]
    fn combined_root_is_length_and_order_sensitive() {
        let a = leaf_hash(b"a");
        let b = leaf_hash(b"b");
        assert_ne!(combined_root(&[a, b]), combined_root(&[b, a]));
        assert_ne!(combined_root(&[a]), combined_root(&[a, a]));
        assert_eq!(combined_root(&[a, b]), combined_root(&[a, b]));
    }

    #[test]
    fn domain_separation_leaf_vs_node() {
        // A 64-byte "encoding" that mimics two concatenated digests must
        // not collide with the internal node over those digests.
        let l = leaf_hash(b"left");
        let r = leaf_hash(b"right");
        let mut fake = Vec::new();
        fake.extend_from_slice(&l);
        fake.extend_from_slice(&r);
        assert_ne!(leaf_hash(&fake), node_hash(&l, &r));
    }
}
