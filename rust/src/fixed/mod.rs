//! Fixed-point arithmetic — the numeric core of Valori (paper §5.1, §6).
//!
//! Valori replaces IEEE-754 floating point with signed fixed-point formats
//! whose operations lower to ordinary integer ALU instructions, which are
//! bit-identical across x86, ARM, RISC-V and WASM. Precision is a
//! *configurable memory contract* (paper §6, Table 2): deployments choose a
//! format (Q8.24, Q16.16, Q32.32) and determinism is preserved regardless of
//! the choice, because every operation stays integer-associative.
//!
//! Layout of this module:
//! - [`format`]   — the [`format::FixedFormat`] trait (the precision contract)
//!   and the concrete formats [`Q8_24`], [`Q16_16`], [`Q32_32`].
//! - [`ops`]      — saturating scalar helpers shared by the formats.
//! - [`isqrt`]    — deterministic integer square root (used by fixed-point
//!   L2 normalization).

#![forbid(unsafe_code)]

pub mod format;
pub mod isqrt;
pub mod ops;

pub use format::{FixedFormat, Q16_16, Q32_32, Q8_24};
pub use isqrt::{isqrt_u128, isqrt_u64};
