//! Integration E9: cross-implementation bit identity.
//!
//! The paper's determinism claim rests on integer arithmetic being exact
//! and platform-independent. We verify it across *implementations*, which
//! is stronger than across runs: the Rust kernel's integer distances must
//! equal the AOT-compiled Pallas/XLA kernel's outputs bit-for-bit, while
//! the floating-point pipelines are allowed to (and do) diverge.
//!
//! Requires `make artifacts`; tests skip with a notice otherwise.

use valori::distance::{dot_q16, l2sq_q16};
use valori::fixed::{FixedFormat, Q16_16};
use valori::hash::XorShift64;
use valori::runtime::{artifacts_available, artifacts_dir, DistanceEngine, Engine, Manifest};

fn setup() -> Option<(Engine, Manifest)> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let engine = Engine::cpu().unwrap();
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    Some((engine, manifest))
}

fn contract_vec(rng: &mut XorShift64, dim: usize) -> Vec<i32> {
    // within the boundary contract: |raw| <= 2^18 (DESIGN §6)
    (0..dim).map(|_| (rng.next_f64() * 524288.0 - 262144.0) as i32).collect()
}

#[test]
fn integer_distances_bit_identical_rust_vs_xla() {
    let Some((engine, m)) = setup() else { return };
    let de = DistanceEngine::load(&engine, artifacts_dir(), m.model.d_model, m.model.db_rows)
        .unwrap();
    let dim = m.model.d_model;
    let mut rng = XorShift64::new(0xE9);
    for trial in 0..5 {
        let n = [1usize, 7, 100, 512, 1024][trial];
        let db: Vec<i32> = (0..n).flat_map(|_| contract_vec(&mut rng, dim)).collect();
        let q = contract_vec(&mut rng, dim);
        let xla_l2 = de.l2sq_q16(&q, &db).unwrap();
        let xla_dot = de.dot_q16(&q, &db).unwrap();
        for row in 0..n {
            let r = &db[row * dim..(row + 1) * dim];
            assert_eq!(xla_l2[row], l2sq_q16(&q, r), "l2 trial {trial} row {row}");
            assert_eq!(xla_dot[row], dot_q16(&q, r), "dot trial {trial} row {row}");
        }
    }
}

#[test]
fn quantizer_bit_identical_rust_vs_pallas() {
    let Some((engine, m)) = setup() else { return };
    let quantize = engine.load_hlo(artifacts_dir().join("quantize.hlo.txt")).unwrap();
    let (b, d) = (m.model.batch, m.model.d_model);
    let mut rng = XorShift64::new(0x9A17);
    // values spanning the interesting regimes incl. ties and saturation
    let mut xs: Vec<f32> = (0..b * d).map(|_| rng.next_f32_range(-4.0, 4.0)).collect();
    xs[0] = 0.0;
    xs[1] = 2.5 / 65536.0; // rounding tie
    xs[2] = -2.5 / 65536.0;
    xs[3] = 40000.0; // saturates
    xs[4] = -40000.0;
    let lit = valori::runtime::engine::literal_f32(&xs, &[b, d]).unwrap();
    let out = quantize.run(&[lit]).unwrap();
    let pallas: Vec<i32> = out.to_vec::<i32>().unwrap();
    for (i, &x) in xs.iter().enumerate() {
        let rust = Q16_16::quantize(x as f64);
        assert_eq!(pallas[i], rust, "x = {x} at {i}");
    }
}

#[test]
fn float_pipeline_is_allowed_to_diverge_and_does() {
    // Control experiment: the f32 L2 distances computed by XLA generally
    // do NOT bit-match a naive Rust loop — float results are evaluation-
    // order-dependent (paper §2.1). This is the contrast that motivates
    // the integer kernel.
    let Some((engine, m)) = setup() else { return };
    let de = DistanceEngine::load(&engine, artifacts_dir(), m.model.d_model, m.model.db_rows)
        .unwrap();
    let dim = m.model.d_model;
    let mut rng = XorShift64::new(0xF107);
    let n = 256;
    let db: Vec<f32> = (0..n * dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
    let q: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
    let xla = de.l2sq_f32(&q, &db).unwrap();
    let mut diverged = 0;
    for row in 0..n {
        let r = &db[row * dim..(row + 1) * dim];
        let rust = valori::distance::float::l2sq_f32_seq(&q, r);
        if rust.to_bits() != xla[row].to_bits() {
            diverged += 1;
        }
        // mathematically they still agree
        assert!((rust - xla[row]).abs() < 1e-3);
    }
    assert!(
        diverged > n / 4,
        "expected widespread f32 divergence, got {diverged}/{n} \
         (if this fails the host may be computing sequentially — inspect!)"
    );
}

#[test]
fn kernel_search_unaffected_by_which_impl_computed_distances() {
    // End-to-end: rank 100 db vectors by distance using (a) the Rust
    // kernel and (b) the XLA integer kernel; the *orderings* must be
    // identical, including tie handling.
    let Some((engine, m)) = setup() else { return };
    let de = DistanceEngine::load(&engine, artifacts_dir(), m.model.d_model, m.model.db_rows)
        .unwrap();
    let dim = m.model.d_model;
    let mut rng = XorShift64::new(0x5EED);
    let n = 100;
    let mut db: Vec<i32> = (0..n).flat_map(|_| contract_vec(&mut rng, dim)).collect();
    // plant exact duplicates to create distance ties
    let dup: Vec<i32> = db[..dim].to_vec();
    db.extend_from_slice(&dup);
    let q = contract_vec(&mut rng, dim);

    let xla = de.l2sq_q16(&q, &db).unwrap();
    let rows = n + 1;
    let mut order_xla: Vec<(i64, usize)> =
        xla.iter().copied().zip(0..rows).map(|(d, i)| (d, i)).collect();
    order_xla.sort();
    let mut order_rust: Vec<(i64, usize)> = (0..rows)
        .map(|i| (l2sq_q16(&q, &db[i * dim..(i + 1) * dim]), i))
        .collect();
    order_rust.sort();
    assert_eq!(order_xla, order_rust);
    // the planted duplicate ties exactly with row 0
    assert_eq!(xla[0], xla[rows - 1]);
}
