//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Deterministic by construction: cases are generated from a seeded
//! [`XorShift64`], and on failure the framework performs greedy shrinking
//! using the strategy's `shrink` candidates, then panics with the minimal
//! failing input and the seed that reproduces it.
//!
//! ```
//! use valori::testing::{check, Gen, Strategy};
//! check("addition commutes", 100, Gen::pair(Gen::i32_range(-100, 100), Gen::i32_range(-100, 100)),
//!       |(a, b)| a + b == b + a);
//! ```

#![forbid(unsafe_code)]

use crate::hash::XorShift64;
use std::fmt::Debug;

/// A value-generation + shrinking strategy.
pub trait Strategy {
    type Value: Clone + Debug;

    /// Generate one value.
    fn generate(&self, rng: &mut XorShift64) -> Self::Value;

    /// Candidate "smaller" values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `cases` generated checks of `prop`; shrink and panic on failure.
pub fn check<S: Strategy>(name: &str, cases: usize, strategy: S, prop: impl Fn(&S::Value) -> bool) {
    check_seeded(name, cases, 0x7a10_11u64 ^ crate::hash::fnv1a64(name.as_bytes()), strategy, prop)
}

/// Like [`check`] with an explicit seed (printed on failure for replay).
pub fn check_seeded<S: Strategy>(
    name: &str,
    cases: usize,
    seed: u64,
    strategy: S,
    prop: impl Fn(&S::Value) -> bool,
) {
    let mut rng = XorShift64::new(seed);
    for case in 0..cases {
        let value = strategy.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&strategy, value, &prop);
            panic!(
                "property '{name}' failed (seed {seed:#x}, case {case});\n  minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<S: Strategy>(
    strategy: &S,
    mut failing: S::Value,
    prop: &impl Fn(&S::Value) -> bool,
) -> S::Value {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in strategy.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Built-in strategies.
pub struct Gen;

impl Gen {
    pub fn i32_range(lo: i32, hi: i32) -> I32Range {
        assert!(lo <= hi);
        I32Range { lo, hi }
    }

    pub fn f32_range(lo: f32, hi: f32) -> F32Range {
        assert!(lo <= hi);
        F32Range { lo, hi }
    }

    pub fn u64_below(n: u64) -> U64Below {
        assert!(n > 0);
        U64Below { n }
    }

    /// Vector of fixed length.
    pub fn vec_of<S: Strategy>(elem: S, len: usize) -> VecOf<S> {
        VecOf { elem, min: len, max: len }
    }

    /// Vector with length in `[min, max]`.
    pub fn vec_len<S: Strategy>(elem: S, min: usize, max: usize) -> VecOf<S> {
        assert!(min <= max);
        VecOf { elem, min, max }
    }

    pub fn pair<A: Strategy, B: Strategy>(a: A, b: B) -> Pair<A, B> {
        Pair { a, b }
    }
}

pub struct I32Range {
    lo: i32,
    hi: i32,
}

impl Strategy for I32Range {
    type Value = i32;

    fn generate(&self, rng: &mut XorShift64) -> i32 {
        let span = (self.hi as i64 - self.lo as i64 + 1) as u64;
        (self.lo as i64 + rng.next_below(span) as i64) as i32
    }

    fn shrink(&self, v: &i32) -> Vec<i32> {
        let mut out = Vec::new();
        let anchor = 0i32.clamp(self.lo, self.hi);
        if *v != anchor {
            out.push(anchor);
            out.push(anchor + (v - anchor) / 2);
        }
        out
    }
}

pub struct F32Range {
    lo: f32,
    hi: f32,
}

impl Strategy for F32Range {
    type Value = f32;

    fn generate(&self, rng: &mut XorShift64) -> f32 {
        rng.next_f32_range(self.lo, self.hi)
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let anchor = 0f32.clamp(self.lo, self.hi);
        if *v != anchor {
            vec![anchor, anchor + (v - anchor) / 2.0]
        } else {
            Vec::new()
        }
    }
}

pub struct U64Below {
    n: u64,
}

impl Strategy for U64Below {
    type Value = u64;

    fn generate(&self, rng: &mut XorShift64) -> u64 {
        rng.next_below(self.n)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        if *v == 0 {
            Vec::new()
        } else {
            vec![0, v / 2, v - 1]
        }
    }
}

pub struct VecOf<S: Strategy> {
    elem: S,
    min: usize,
    max: usize,
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;

    fn generate(&self, rng: &mut XorShift64) -> Vec<S::Value> {
        let len = self.min + rng.next_below((self.max - self.min + 1) as u64) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        // shrink length first
        if v.len() > self.min {
            out.push(v[..self.min].to_vec());
            out.push(v[..(self.min + v.len()) / 2].to_vec());
        }
        // then shrink one element at a time (first few positions)
        for i in 0..v.len().min(4) {
            for cand in self.elem.shrink(&v[i]) {
                let mut copy = v.clone();
                copy[i] = cand;
                out.push(copy);
            }
        }
        out
    }
}

pub struct Pair<A: Strategy, B: Strategy> {
    a: A,
    b: B,
}

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut XorShift64) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for ca in self.a.shrink(&v.0) {
            out.push((ca, v.1.clone()));
        }
        for cb in self.b.shrink(&v.1) {
            out.push((v.0.clone(), cb));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative for non-min", 200, Gen::i32_range(-1000, 1000), |v| {
            v.abs() >= 0
        });
    }

    #[test]
    fn generation_is_deterministic() {
        let s = Gen::vec_len(Gen::i32_range(0, 100), 0, 10);
        let mut r1 = XorShift64::new(9);
        let mut r2 = XorShift64::new(9);
        for _ in 0..50 {
            assert_eq!(s.generate(&mut r1), s.generate(&mut r2));
        }
    }

    #[test]
    fn failing_property_panics_with_counterexample() {
        let result = std::panic::catch_unwind(|| {
            check("all values are small", 500, Gen::i32_range(0, 1000), |v| *v < 900);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal counterexample"), "{msg}");
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // failure iff v >= 573; shrinker should descend toward 573-ish,
        // certainly below the typical first random failure.
        let result = std::panic::catch_unwind(|| {
            check_seeded("threshold", 500, 77, Gen::i32_range(0, 100_000), |v| *v < 573);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // extract the number from "counterexample: N"
        let n: i64 =
            msg.rsplit(": ").next().unwrap().trim().parse().expect("counterexample number");
        assert!(n < 10_000, "shrinking didn't descend: {n}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        let s = Gen::vec_len(Gen::i32_range(-5, 5), 2, 7);
        let mut rng = XorShift64::new(4);
        for _ in 0..100 {
            let v = s.generate(&mut rng);
            assert!((2..=7).contains(&v.len()));
            assert!(v.iter().all(|x| (-5..=5).contains(x)));
        }
    }

    #[test]
    fn pair_strategy_shrinks_both_sides() {
        let s = Gen::pair(Gen::i32_range(0, 10), Gen::i32_range(0, 10));
        let cands = s.shrink(&(10, 10));
        assert!(cands.iter().any(|(a, _)| *a == 0));
        assert!(cands.iter().any(|(_, b)| *b == 0));
    }
}
