//! Distance computation — fixed-point (deterministic) and float (baseline).
//!
//! The index layer is generic over a [`Scalar`] so the *same* HNSW code can
//! be instantiated with:
//! - `i32` (Q16.16) / `i64` (Q32.32) — integer distances, total order,
//!   deterministic everywhere (Valori proper), and
//! - `f32` — the floating-point baseline the paper compares against
//!   (Table 3), with an [`OrderedF32`] total order for heap use.
//!
//! The float module also exposes *reduction-order variants* of the same dot
//! product ([`float::dot_f32_seq`], [`float::dot_f32_rev`],
//! [`float::dot_f32_pairwise`]): same inputs, different IEEE-754 evaluation
//! orders, generally different bits. They power the divergence experiments
//! (Table 1's mechanism, isolated).

#![forbid(unsafe_code)]

pub mod float;

use crate::codec::{DecodeError, Decoder, Encoder};
use std::cmp::Ordering;
use std::fmt::Debug;

/// Distance metric selection (part of the collection config).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Squared Euclidean distance (smaller = closer).
    L2,
    /// Negative inner product (smaller = closer ⇒ larger dot = closer).
    InnerProduct,
    /// Cosine distance; under the `normalize` boundary policy vectors are
    /// unit-norm so this equals `InnerProduct`. The kernel treats it as
    /// such (documented contract).
    Cosine,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::InnerProduct => "ip",
            Metric::Cosine => "cosine",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "l2" => Some(Metric::L2),
            "ip" | "dot" => Some(Metric::InnerProduct),
            "cosine" | "cos" => Some(Metric::Cosine),
            _ => None,
        }
    }

    /// Stable on-disk tag.
    pub fn tag(&self) -> u8 {
        match self {
            Metric::L2 => 0,
            Metric::InnerProduct => 1,
            Metric::Cosine => 2,
        }
    }

    pub fn from_tag(t: u8) -> Option<Self> {
        match t {
            0 => Some(Metric::L2),
            1 => Some(Metric::InnerProduct),
            2 => Some(Metric::Cosine),
            _ => None,
        }
    }
}

/// Scalar element type the index can be instantiated over.
///
/// `Dist` must be a *total order* — this is where float non-determinism is
/// quarantined: integer `Dist`s are exact; the f32 baseline uses
/// [`OrderedF32`] (IEEE total_cmp) so heaps behave, but its *values* still
/// depend on evaluation order, which is exactly the paper's point.
pub trait Scalar: Copy + Debug + PartialEq + 'static {
    type Dist: Copy + Ord + Debug;

    /// Distance under `metric` (smaller = closer for every metric).
    fn distance(metric: Metric, a: &[Self], b: &[Self]) -> Self::Dist;

    /// Score `query` against a contiguous block of vectors laid out
    /// back-to-back in `block` (`block.len() == dim * out.len()`, row `r`
    /// at `block[r*dim..(r+1)*dim]`), writing one distance per row into
    /// `out`. Each row is scored independently with exact per-row
    /// arithmetic, so the results are bit-identical to calling
    /// [`Scalar::distance`] once per row — the batch form only changes the
    /// memory access pattern (one contiguous sweep), never the values.
    /// `dim` must be non-zero; callers with degenerate dimensions use the
    /// per-row path.
    fn distance_block(
        metric: Metric,
        query: &[Self],
        block: &[Self],
        dim: usize,
        out: &mut [Self::Dist],
    ) {
        debug_assert_eq!(block.len(), dim * out.len(), "block/out shape mismatch");
        for (row, d) in block.chunks_exact(dim).zip(out.iter_mut()) {
            *d = Self::distance(metric, query, row);
        }
    }

    /// A distance value larger than any real one (sentinel for init).
    fn max_dist() -> Self::Dist;

    /// Append one scalar to a deterministic byte stream (snapshots).
    fn encode(self, e: &mut Encoder);

    /// Read one scalar back.
    fn decode(d: &mut Decoder) -> std::result::Result<Self, DecodeError>;

    /// Distance rendered as a real number for reporting/JSON (never used
    /// for ordering).
    // lint: float-boundary — display-only rendering, never ordered on
    fn dist_to_f64(d: Self::Dist) -> f64;

    /// SQ8 quantization hook: the Q16.16 raw value of this scalar, or
    /// `None` for scalar types the quantized scan tier does not cover
    /// (their code arenas stay empty and search always takes the exact
    /// path). Only `i32` — the Q16.16 representation the boundary
    /// contract bounds — opts in; quantizing Q32.32 or the f32 baseline
    /// would need a different scale derivation.
    #[inline]
    fn as_q16_raw(self) -> Option<i32> {
        None
    }
}

/// Q16.16 raw scalars: wide i64 distances (Q32.32). Integer math only.
impl Scalar for i32 {
    type Dist = i64;

    #[inline]
    fn distance(metric: Metric, a: &[Self], b: &[Self]) -> i64 {
        match metric {
            Metric::L2 => l2sq_q16(a, b),
            Metric::InnerProduct | Metric::Cosine => dot_q16(a, b).saturating_neg(),
        }
    }

    #[inline]
    fn distance_block(metric: Metric, query: &[i32], block: &[i32], dim: usize, out: &mut [i64]) {
        match metric {
            Metric::L2 => l2sq_q16_block(query, block, dim, out),
            Metric::InnerProduct | Metric::Cosine => {
                dot_q16_block(query, block, dim, out);
                // Same negation the scalar path applies per value.
                for d in out.iter_mut() {
                    *d = d.saturating_neg();
                }
            }
        }
    }

    #[inline]
    fn max_dist() -> i64 {
        i64::MAX
    }

    #[inline]
    fn encode(self, e: &mut Encoder) {
        e.put_i32(self);
    }

    #[inline]
    fn decode(d: &mut Decoder) -> std::result::Result<Self, DecodeError> {
        d.get_i32()
    }

    // lint: float-boundary — display-only rendering, never ordered on
    #[inline]
    fn dist_to_f64(d: i64) -> f64 {
        // Q32.32 wide value -> real
        d as f64 / 4294967296.0
    }

    #[inline]
    fn as_q16_raw(self) -> Option<i32> {
        Some(self)
    }
}

/// Q32.32 raw scalars: i128 distances. Integer math only.
impl Scalar for i64 {
    type Dist = i128;

    #[inline]
    fn distance(metric: Metric, a: &[Self], b: &[Self]) -> i128 {
        match metric {
            Metric::L2 => {
                let mut acc: i128 = 0;
                for i in 0..a.len() {
                    let d = a[i].saturating_sub(b[i]) as i128;
                    acc = acc.saturating_add(d * d);
                }
                acc
            }
            Metric::InnerProduct | Metric::Cosine => {
                let mut acc: i128 = 0;
                for i in 0..a.len() {
                    acc = acc.saturating_add((a[i] as i128) * (b[i] as i128));
                }
                acc.saturating_neg()
            }
        }
    }

    #[inline]
    fn max_dist() -> i128 {
        i128::MAX
    }

    #[inline]
    fn encode(self, e: &mut Encoder) {
        e.put_i64(self);
    }

    #[inline]
    fn decode(d: &mut Decoder) -> std::result::Result<Self, DecodeError> {
        d.get_i64()
    }

    // lint: float-boundary — display-only rendering, never ordered on
    #[inline]
    fn dist_to_f64(d: i128) -> f64 {
        // Q64.64 wide value -> real
        d as f64 / 2f64.powi(64)
    }
}

/// f32 baseline scalars: distances are [`OrderedF32`] (total order), values
/// computed with the plain sequential loop (what a naive scalar build does).
// lint: float-boundary — the float *baseline* instantiation, measured but never hashed
impl Scalar for f32 {
    type Dist = OrderedF32;

    #[inline]
    fn distance(metric: Metric, a: &[Self], b: &[Self]) -> OrderedF32 {
        match metric {
            Metric::L2 => OrderedF32(float::l2sq_f32_seq(a, b)),
            Metric::InnerProduct | Metric::Cosine => OrderedF32(-float::dot_f32_seq(a, b)),
        }
    }

    #[inline]
    fn max_dist() -> OrderedF32 {
        OrderedF32(f32::INFINITY)
    }

    #[inline]
    fn encode(self, e: &mut Encoder) {
        e.put_f32(self);
    }

    #[inline]
    fn decode(d: &mut Decoder) -> std::result::Result<Self, DecodeError> {
        d.get_f32()
    }

    #[inline]
    fn dist_to_f64(d: OrderedF32) -> f64 {
        d.0 as f64
    }
}

/// Q16.16 dot product, i64 accumulator (paper §5.1). Under the boundary
/// contract (|raw| ≤ 2^18, dim ≤ 16384 — enforced by the kernel for BOTH
/// the float and the canonical/replication ingest paths) each term is
/// ≤ 2^36 and the sum ≤ 2^50 ≪ i64::MAX, so plain wrapping adds are exact.
/// Plain `+` (instead of `saturating_add`) is what lets LLVM auto-vectorize
/// the loop with integer SIMD — exact, order-independent, and therefore
/// still bit-identical to the scalar loop and to the Pallas int64 kernel
/// (experiment E9). §Perf: ~3× faster than the saturating version.
///
/// Contract: `a.len() == b.len()`. A mismatch is a caller bug, caught by
/// the `debug_assert` in debug builds; exact-length enforcement for both
/// operands lives at the public entry points (`state::kernel` dim-checks
/// every command and query; `FlatIndex::search`/`Hnsw::search` assert the
/// query dim), so no public search path can reach this loop mismatched.
/// In release this function itself panics if `b` is shorter (the
/// `&b[..a.len()]` reslice, which also lets LLVM drop the inner bounds
/// checks) — the pre-refactor `min()` silent truncation is gone.
#[inline]
pub fn dot_q16(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len(), "dot_q16: equal-length contract violated");
    let b = &b[..a.len()];
    let mut acc: i64 = 0;
    for i in 0..a.len() {
        acc += (a[i] as i64) * (b[i] as i64);
    }
    acc
}

/// Q16.16 squared L2 distance, i64 accumulator (same contract argument —
/// and the same equal-length contract — as [`dot_q16`]).
#[inline]
pub fn l2sq_q16(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len(), "l2sq_q16: equal-length contract violated");
    let b = &b[..a.len()];
    let mut acc: i64 = 0;
    for i in 0..a.len() {
        let d = (a[i] as i64) - (b[i] as i64);
        acc += d * d;
    }
    acc
}

/// Blocked Q16.16 dot kernel: score `query` against `out.len()` vectors
/// stored back-to-back in `block` (row `r` at `block[r*dim..(r+1)*dim]`).
/// One call sweeps a contiguous arena run, so the loads stream linearly
/// through cache and the inner loop auto-vectorizes; every row uses the
/// exact integer accumulation of [`dot_q16`], so the output is
/// bit-identical to the per-row scalar calls in any build. `dim` must be
/// non-zero and equal to `query.len()`.
#[inline]
pub fn dot_q16_block(query: &[i32], block: &[i32], dim: usize, out: &mut [i64]) {
    debug_assert!(dim > 0, "dot_q16_block: dim must be non-zero");
    debug_assert_eq!(query.len(), dim, "dot_q16_block: query/dim mismatch");
    debug_assert_eq!(block.len(), dim * out.len(), "dot_q16_block: block shape mismatch");
    for (row, d) in block.chunks_exact(dim).zip(out.iter_mut()) {
        *d = dot_q16(query, row);
    }
}

/// Blocked Q16.16 squared-L2 kernel (same layout and exactness contract as
/// [`dot_q16_block`]).
#[inline]
pub fn l2sq_q16_block(query: &[i32], block: &[i32], dim: usize, out: &mut [i64]) {
    debug_assert!(dim > 0, "l2sq_q16_block: dim must be non-zero");
    debug_assert_eq!(query.len(), dim, "l2sq_q16_block: query/dim mismatch");
    debug_assert_eq!(block.len(), dim * out.len(), "l2sq_q16_block: block shape mismatch");
    for (row, d) in block.chunks_exact(dim).zip(out.iter_mut()) {
        *d = l2sq_q16(query, row);
    }
}

/// f32 wrapper with IEEE-754 `total_cmp` ordering, so the float baseline
/// can share the integer index code (heaps need `Ord`).
// lint: float-boundary — baseline-only ordering wrapper (total_cmp)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrderedF32(pub f32);

impl Eq for OrderedF32 {}

impl PartialOrd for OrderedF32 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedF32 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FixedFormat, Q16_16};

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    #[test]
    fn dot_q16_matches_real_values() {
        let a = vec![q(1.0), q(2.0), q(-0.5)];
        let b = vec![q(1.0), q(0.5), q(2.0)];
        // 1 + 1 - 1 = 1
        assert_eq!(Q16_16::wide_to_f64(dot_q16(&a, &b)), 1.0);
    }

    #[test]
    fn l2sq_q16_matches_real_values() {
        let a = vec![q(1.0), q(1.0)];
        let b = vec![q(0.0), q(0.0)];
        assert_eq!(Q16_16::wide_to_f64(l2sq_q16(&a, &b)), 2.0);
    }

    #[test]
    fn scalar_i32_metrics() {
        let a = vec![q(1.0), q(0.0)];
        let b = vec![q(0.0), q(1.0)];
        let d_l2 = <i32 as Scalar>::distance(Metric::L2, &a, &b);
        assert_eq!(Q16_16::wide_to_f64(d_l2), 2.0);
        let d_ip_ab = <i32 as Scalar>::distance(Metric::InnerProduct, &a, &b);
        let d_ip_aa = <i32 as Scalar>::distance(Metric::InnerProduct, &a, &a);
        // a is closer to itself than to the orthogonal b
        assert!(d_ip_aa < d_ip_ab);
    }

    #[test]
    fn cosine_equals_ip() {
        let a = vec![q(0.6), q(0.8)];
        let b = vec![q(1.0), q(0.0)];
        assert_eq!(
            <i32 as Scalar>::distance(Metric::Cosine, &a, &b),
            <i32 as Scalar>::distance(Metric::InnerProduct, &a, &b)
        );
    }

    #[test]
    fn ordered_f32_total_order() {
        let mut v = vec![
            OrderedF32(1.0),
            OrderedF32(f32::NAN),
            OrderedF32(-1.0),
            OrderedF32(0.0),
            OrderedF32(-0.0),
        ];
        v.sort();
        assert_eq!(v[0].0, -1.0);
        // -0.0 sorts before +0.0 under total_cmp
        assert!(v[1].0.to_bits() == (-0.0f32).to_bits());
        assert!(v[4].0.is_nan());
    }

    #[test]
    fn f32_scalar_baseline() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        assert_eq!(<f32 as Scalar>::distance(Metric::L2, &a, &b).0, 2.0);
    }

    #[test]
    fn q32_scalar_metrics() {
        use crate::fixed::Q32_32;
        let q32 = |x: f64| Q32_32::quantize(x);
        let a = vec![q32(3.0), q32(0.0)];
        let b = vec![q32(0.0), q32(4.0)];
        let d = <i64 as Scalar>::distance(Metric::L2, &a, &b);
        // 25.0 in Q64.64
        let real = d as f64 / 2f64.powi(64);
        assert!((real - 25.0).abs() < 1e-9);
    }

    #[test]
    fn metric_tags_roundtrip() {
        for m in [Metric::L2, Metric::InnerProduct, Metric::Cosine] {
            assert_eq!(Metric::from_tag(m.tag()), Some(m));
            assert_eq!(Metric::from_name(m.name()), Some(m));
        }
        assert_eq!(Metric::from_tag(9), None);
    }

    #[test]
    fn block_kernels_match_per_row_scalar_calls() {
        let dim = 7; // odd on purpose: exercises the vectorizer's tail path
        let rows = 13;
        let qv: Vec<i32> = (0..dim).map(|i| q(((i * 13 % 100) as f64 - 50.0) / 50.0)).collect();
        let block: Vec<i32> = (0..dim * rows)
            .map(|i| q(((i * 7 % 160) as f64 - 80.0) / 80.0))
            .collect();
        let mut dots = vec![0i64; rows];
        let mut l2s = vec![0i64; rows];
        dot_q16_block(&qv, &block, dim, &mut dots);
        l2sq_q16_block(&qv, &block, dim, &mut l2s);
        for r in 0..rows {
            let row = &block[r * dim..(r + 1) * dim];
            assert_eq!(dots[r], dot_q16(&qv, row), "dot row {r}");
            assert_eq!(l2s[r], l2sq_q16(&qv, row), "l2 row {r}");
        }
        // The trait hook agrees with the free functions (incl. IP negation).
        let mut via_trait = vec![0i64; rows];
        <i32 as Scalar>::distance_block(Metric::InnerProduct, &qv, &block, dim, &mut via_trait);
        for r in 0..rows {
            let row = &block[r * dim..(r + 1) * dim];
            assert_eq!(via_trait[r], <i32 as Scalar>::distance(Metric::InnerProduct, &qv, row));
        }
    }

    #[test]
    fn default_distance_block_covers_f32() {
        let dim = 3;
        let qv = vec![0.5f32, -0.25, 1.0];
        let block = vec![0.1f32, 0.2, 0.3, -0.4, 0.5, -0.6];
        let mut out = vec![OrderedF32(0.0); 2];
        <f32 as Scalar>::distance_block(Metric::L2, &qv, &block, dim, &mut out);
        assert_eq!(out[0], <f32 as Scalar>::distance(Metric::L2, &qv, &block[0..3]));
        assert_eq!(out[1], <f32 as Scalar>::distance(Metric::L2, &qv, &block[3..6]));
    }

    #[test]
    fn dot_determinism_repeated() {
        let a: Vec<i32> = (0..512).map(|i| q(((i * 31 % 200) as f64 - 100.0) / 100.0)).collect();
        let b: Vec<i32> = (0..512).map(|i| q(((i * 17 % 200) as f64 - 100.0) / 100.0)).collect();
        let d1 = dot_q16(&a, &b);
        for _ in 0..10 {
            assert_eq!(dot_q16(&a, &b), d1);
        }
    }
}
