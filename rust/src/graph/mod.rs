//! Typed links between memories — the `link(a, b)` command surface the
//! paper's §3.1 command vocabulary includes.
//!
//! Agent memories are not just points in embedding space; they reference
//! each other ("this fact supersedes that one", "these belong to the same
//! episode"). Valori stores links inside the deterministic state machine so
//! they replay and snapshot with everything else. Structures are `BTreeMap`
//! / `BTreeSet` so iteration (and therefore serialization and hashing) is
//! canonical.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use std::collections::{BTreeMap, BTreeSet};

/// Directed link graph over external vector ids.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LinkGraph {
    /// from -> set of to.
    out: BTreeMap<u64, BTreeSet<u64>>,
    /// to -> set of from (kept for O(log) reverse queries and for cleaning
    /// up when a node is deleted).
    incoming: BTreeMap<u64, BTreeSet<u64>>,
    edge_count: usize,
}

impl LinkGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn edge_count(&self) -> usize {
        self.edge_count
    }

    /// Add a directed edge. Returns false if it already existed.
    pub fn link(&mut self, from: u64, to: u64) -> bool {
        let inserted = self.out.entry(from).or_default().insert(to);
        if inserted {
            self.incoming.entry(to).or_default().insert(from);
            self.edge_count += 1;
        }
        inserted
    }

    /// Remove a directed edge. Returns false if absent.
    pub fn unlink(&mut self, from: u64, to: u64) -> bool {
        let removed = self.out.get_mut(&from).map(|s| s.remove(&to)).unwrap_or(false);
        if removed {
            if self.out.get(&from).is_some_and(|s| s.is_empty()) {
                self.out.remove(&from);
            }
            if let Some(s) = self.incoming.get_mut(&to) {
                s.remove(&from);
                if s.is_empty() {
                    self.incoming.remove(&to);
                }
            }
            self.edge_count -= 1;
        }
        removed
    }

    /// Drop every edge touching `id` (called when a vector is deleted).
    pub fn remove_node(&mut self, id: u64) {
        if let Some(outs) = self.out.remove(&id) {
            self.edge_count -= outs.len();
            for to in outs {
                if let Some(s) = self.incoming.get_mut(&to) {
                    s.remove(&id);
                    if s.is_empty() {
                        self.incoming.remove(&to);
                    }
                }
            }
        }
        if let Some(ins) = self.incoming.remove(&id) {
            for from in ins {
                if let Some(s) = self.out.get_mut(&from) {
                    if s.remove(&id) {
                        self.edge_count -= 1;
                    }
                    if s.is_empty() {
                        self.out.remove(&from);
                    }
                }
            }
        }
    }

    pub fn has_link(&self, from: u64, to: u64) -> bool {
        self.out.get(&from).is_some_and(|s| s.contains(&to))
    }

    /// Outgoing neighbours of `from`, ascending.
    pub fn links_from(&self, from: u64) -> Vec<u64> {
        self.out.get(&from).map(|s| s.iter().copied().collect()).unwrap_or_default()
    }

    /// Incoming neighbours of `to`, ascending.
    pub fn links_to(&self, to: u64) -> Vec<u64> {
        self.incoming.get(&to).map(|s| s.iter().copied().collect()).unwrap_or_default()
    }

    /// Canonical serialization: sorted by (from, to).
    pub fn encode(&self, e: &mut Encoder) {
        e.put_u32(self.out.len() as u32);
        for (from, tos) in &self.out {
            e.put_u64(*from);
            e.put_u32(tos.len() as u32);
            for to in tos {
                e.put_u64(*to);
            }
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let n = d.get_u32()? as usize;
        let mut g = Self::new();
        for _ in 0..n {
            let from = d.get_u64()?;
            let cnt = d.get_u32()? as usize;
            for _ in 0..cnt {
                let to = d.get_u64()?;
                g.link(from, to);
            }
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_unlink() {
        let mut g = LinkGraph::new();
        assert!(g.link(1, 2));
        assert!(!g.link(1, 2)); // idempotent
        assert_eq!(g.edge_count(), 1);
        assert!(g.has_link(1, 2));
        assert!(!g.has_link(2, 1)); // directed
        assert!(g.unlink(1, 2));
        assert!(!g.unlink(1, 2));
        assert_eq!(g.edge_count(), 0);
    }

    #[test]
    fn neighbours_sorted() {
        let mut g = LinkGraph::new();
        g.link(1, 30);
        g.link(1, 10);
        g.link(1, 20);
        g.link(99, 10);
        assert_eq!(g.links_from(1), vec![10, 20, 30]);
        assert_eq!(g.links_to(10), vec![1, 99]);
        assert!(g.links_from(555).is_empty());
    }

    #[test]
    fn remove_node_cleans_both_directions() {
        let mut g = LinkGraph::new();
        g.link(1, 2);
        g.link(2, 3);
        g.link(3, 2);
        g.remove_node(2);
        assert_eq!(g.edge_count(), 0);
        assert!(g.links_from(1).is_empty());
        assert!(g.links_from(3).is_empty());
    }

    #[test]
    fn self_link_allowed_and_removable() {
        let mut g = LinkGraph::new();
        g.link(7, 7);
        assert!(g.has_link(7, 7));
        g.remove_node(7);
        assert_eq!(g.edge_count(), 0);
    }

    #[test]
    fn roundtrip_canonical() {
        let mut g = LinkGraph::new();
        g.link(5, 1);
        g.link(1, 5);
        g.link(1, 2);
        let mut e = Encoder::new();
        g.encode(&mut e);
        let bytes = e.into_vec();
        let g2 = LinkGraph::decode(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(g, g2);
        let mut e2 = Encoder::new();
        g2.encode(&mut e2);
        assert_eq!(bytes, e2.into_vec());
    }
}
