//! Snapshots: bit-exact state serialization + hashing (paper §5.2, §8.1).
//!
//! "Because the state is deterministic, the entire memory can be serialized
//! to a snapshot file. Restoring this snapshot on a different machine
//! guarantees an exact replica of the memory state, down to the last bit."
//!
//! File format:
//!
//! ```text
//! [ magic "VSNP": u32 ][ version: u32 ]
//! [ state_len: u32 ][ state bytes (Kernel::encode_state) ]
//! [ fnv1a64(state): u64 ]
//! [ sha256(state): 32 bytes ]
//! [ crc32(everything above): u32 ]
//! ```
//!
//! The FNV hash is the cheap cross-node comparison value (H_A ≡ H_B); the
//! SHA-256 is the audit-grade digest; the CRC detects storage corruption.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use crate::hash::{crc32, fnv1a64, Sha256};
use crate::state::{Kernel, ShardedKernel};
use std::fs;
use std::path::Path;

pub mod stream;

pub use stream::{
    FrameSource, SnapshotReader, SnapshotWriter, StreamError, StreamManifestEntry, StreamSpec,
    DEFAULT_CHUNK,
};

const SNAP_MAGIC: u32 = 0x56534E50; // "VSNP"
const SNAP_VERSION: u32 = 1;

/// Fixed bytes around the state payload in a `VSNP` frame:
/// magic (4) + version (4) + state length prefix (4) + fnv (8) +
/// sha256 (32) + crc (4).
const FRAME_OVERHEAD: usize = 56;

/// A serialized snapshot plus its digests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    /// Canonical state bytes (what the hashes are computed over).
    pub state: Vec<u8>,
    /// FNV-1a 64 of `state` — the replica-comparison hash.
    pub fnv: u64,
    /// SHA-256 of `state` — the audit digest.
    pub sha256: [u8; 32],
}

/// Snapshot errors.
#[derive(Debug)]
pub enum SnapshotError {
    Io(std::io::Error),
    Decode(DecodeError),
    /// Stored digest does not match recomputed digest — the snapshot was
    /// corrupted or tampered with.
    DigestMismatch { which: &'static str },
    /// CRC failure (storage corruption).
    CrcMismatch,
    /// A restored shard's config does not match its position in the
    /// sharded snapshot (wrong deployment size or shard index).
    ShardMismatch { shard: u32, n_shards: u32, shard_id: u32 },
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "io: {e}"),
            SnapshotError::Decode(e) => write!(f, "decode: {e}"),
            SnapshotError::DigestMismatch { which } => write!(f, "{which} digest mismatch"),
            SnapshotError::CrcMismatch => write!(f, "crc mismatch"),
            SnapshotError::ShardMismatch { shard, n_shards, shard_id } => write!(
                f,
                "shard {shard}: restored config claims shard {shard_id} of {n_shards}"
            ),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

impl From<DecodeError> for SnapshotError {
    fn from(e: DecodeError) -> Self {
        SnapshotError::Decode(e)
    }
}

impl Snapshot {
    /// Capture a kernel's state.
    pub fn capture(kernel: &Kernel) -> Self {
        let state = kernel.to_state_bytes();
        let fnv = fnv1a64(&state);
        let sha256: [u8; 32] = Sha256::digest(&state).into();
        Self { state, fnv, sha256 }
    }

    /// Rebuild a kernel, verifying both digests first.
    pub fn restore(&self) -> Result<Kernel, SnapshotError> {
        if fnv1a64(&self.state) != self.fnv {
            return Err(SnapshotError::DigestMismatch { which: "fnv" });
        }
        let sha: [u8; 32] = Sha256::digest(&self.state).into();
        if sha != self.sha256 {
            return Err(SnapshotError::DigestMismatch { which: "sha256" });
        }
        Ok(Kernel::from_state_bytes(&self.state)?)
    }

    /// Serialize to the on-disk format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Encoder::with_capacity(self.state.len() + 64);
        e.put_u32(SNAP_MAGIC);
        e.put_u32(SNAP_VERSION);
        e.put_bytes(&self.state);
        e.put_u64(self.fnv);
        for &b in &self.sha256 {
            e.put_u8(b);
        }
        let crc = crc32(e.as_slice());
        e.put_u32(crc);
        e.into_vec()
    }

    /// Exact length of [`Self::to_bytes`] without materializing it
    /// (streaming manifests size their chunks from this).
    pub fn encoded_len(&self) -> usize {
        self.state.len() + FRAME_OVERHEAD
    }

    /// Parse + verify the on-disk format.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, SnapshotError> {
        // Length sanity BEFORE the CRC: a truncated file must report
        // `UnexpectedEof` (how much is missing), not a generic
        // `CrcMismatch` — the two call for different operator responses
        // (retry the transfer vs investigate corruption). A corrupted
        // length *field* also lands here, which is the right bias: the
        // declared length is the first thing a resumed transfer needs.
        if bytes.len() < FRAME_OVERHEAD {
            return Err(SnapshotError::Decode(DecodeError::UnexpectedEof {
                need: FRAME_OVERHEAD,
                have: bytes.len(),
            }));
        }
        let state_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let expected = FRAME_OVERHEAD.saturating_add(state_len);
        if bytes.len() < expected {
            return Err(SnapshotError::Decode(DecodeError::UnexpectedEof {
                need: expected,
                have: bytes.len(),
            }));
        }
        // CRC covers everything except the trailing 4 bytes.
        let body = &bytes[..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            return Err(SnapshotError::CrcMismatch);
        }
        let mut d = Decoder::new(body);
        let magic = d.get_u32()?;
        if magic != SNAP_MAGIC {
            return Err(SnapshotError::Decode(DecodeError::BadMagic {
                expected: SNAP_MAGIC,
                found: magic,
            }));
        }
        let version = d.get_u32()?;
        if version != SNAP_VERSION {
            return Err(SnapshotError::Decode(DecodeError::BadVersion {
                expected: SNAP_VERSION,
                found: version,
            }));
        }
        let state = d.get_bytes()?.to_vec();
        let fnv = d.get_u64()?;
        let mut sha256 = [0u8; 32];
        for b in sha256.iter_mut() {
            *b = d.get_u8()?;
        }
        d.finish()?;
        let snap = Self { state, fnv, sha256 };
        // verify digests against the state payload
        if fnv1a64(&snap.state) != snap.fnv {
            return Err(SnapshotError::DigestMismatch { which: "fnv" });
        }
        let sha: [u8; 32] = Sha256::digest(&snap.state).into();
        if sha != snap.sha256 {
            return Err(SnapshotError::DigestMismatch { which: "sha256" });
        }
        Ok(snap)
    }

    /// Write to a file (atomic: tmp + rename).
    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<(), SnapshotError> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, self.to_bytes())?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read + verify from a file.
    pub fn read_file(path: impl AsRef<Path>) -> Result<Self, SnapshotError> {
        let bytes = fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// Hex rendering of the SHA-256 (for logs/audit records).
    pub fn sha256_hex(&self) -> String {
        crate::hash::sha256_hex(&self.sha256)
    }
}

const SHARD_MAGIC: u32 = 0x5653_484D; // "VSHM"
const SHARD_VERSION: u32 = 1;

/// One row of a sharded snapshot's manifest: the digests replicas compare
/// shard-by-shard (cheap FNV for the convergence check, SHA-256 for audit).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardManifestEntry {
    pub shard: u32,
    pub fnv: u64,
    pub sha256: [u8; 32],
}

/// Snapshot of a [`ShardedKernel`]: one full [`Snapshot`] per shard plus a
/// combined root hash.
///
/// File format:
///
/// ```text
/// [ magic "VSHM": u32 ][ version: u32 ][ n_shards: u32 ]
/// n_shards × [ shard snapshot bytes (length-prefixed, full VSNP frame) ]
/// [ root fnv: u64 ]
/// [ crc32(everything above): u32 ]
/// ```
///
/// Each embedded shard frame carries its own digests and CRC, so a reader
/// can verify (and transfer) shards independently; the root hash — a pure
/// function of the per-shard FNV hashes, see
/// [`crate::state::sharded::root_hash_of`] — summarizes the whole
/// deployment in one value two nodes can exchange.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardedSnapshot {
    pub shards: Vec<Snapshot>,
}

impl ShardedSnapshot {
    /// Capture every shard of a sharded kernel.
    pub fn capture(kernel: &ShardedKernel) -> Self {
        Self { shards: kernel.shards().iter().map(Snapshot::capture).collect() }
    }

    /// The per-shard digest manifest.
    pub fn manifest(&self) -> Vec<ShardManifestEntry> {
        self.shards
            .iter()
            .enumerate()
            .map(|(s, snap)| ShardManifestEntry {
                shard: s as u32,
                fnv: snap.fnv,
                sha256: snap.sha256,
            })
            .collect()
    }

    /// Combined root hash (matches [`ShardedKernel::root_hash`]).
    pub fn root_hash(&self) -> u64 {
        let fnvs: Vec<u64> = self.shards.iter().map(|s| s.fnv).collect();
        crate::state::sharded::root_hash_of(&fnvs)
    }

    /// Receipt-grade snapshot digest: SHA-256 over the ordered per-shard
    /// snapshot digests (`sha256(n ‖ d_0 ‖ … ‖ d_{n-1})`, `n` as u32 LE).
    /// This is the `snapshot_hash` field of a state receipt (see
    /// [`crate::proof`]) — a pure function of the per-shard audit
    /// digests, recomputable offline from a snapshot file.
    pub fn receipt_snapshot_hash(&self) -> [u8; 32] {
        let mut h = crate::hash::Sha256::new();
        h.update(&(self.shards.len() as u32).to_le_bytes());
        for snap in &self.shards {
            h.update(&snap.sha256);
        }
        h.finalize()
    }

    /// Rebuild the sharded kernel, verifying every shard's digests and the
    /// shard-spec consistency of the restored configs.
    pub fn restore(&self) -> Result<ShardedKernel, SnapshotError> {
        let n = self.shards.len() as u32;
        let mut kernels = Vec::with_capacity(self.shards.len());
        for (i, snap) in self.shards.iter().enumerate() {
            let kernel = snap.restore()?;
            let spec = kernel.config().shard;
            if spec.n_shards != n || spec.shard_id != i as u32 {
                return Err(SnapshotError::ShardMismatch {
                    shard: i as u32,
                    n_shards: spec.n_shards,
                    shard_id: spec.shard_id,
                });
            }
            kernels.push(kernel);
        }
        if kernels.is_empty() {
            return Err(SnapshotError::Decode(DecodeError::UnexpectedEof { need: 1, have: 0 }));
        }
        Ok(ShardedKernel::from_shards(kernels))
    }

    /// Serialize to the on-disk format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Encoder::with_capacity(64);
        e.put_u32(SHARD_MAGIC);
        e.put_u32(SHARD_VERSION);
        e.put_u32(self.shards.len() as u32);
        for snap in &self.shards {
            e.put_bytes(&snap.to_bytes());
        }
        e.put_u64(self.root_hash());
        let crc = crc32(e.as_slice());
        e.put_u32(crc);
        e.into_vec()
    }

    /// Parse + verify the on-disk format (CRC, per-shard digests, root).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, SnapshotError> {
        Self::truncation_check(bytes)?;
        let body = &bytes[..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            return Err(SnapshotError::CrcMismatch);
        }
        let mut d = Decoder::new(body);
        let magic = d.get_u32()?;
        if magic != SHARD_MAGIC {
            return Err(SnapshotError::Decode(DecodeError::BadMagic {
                expected: SHARD_MAGIC,
                found: magic,
            }));
        }
        let version = d.get_u32()?;
        if version != SHARD_VERSION {
            return Err(SnapshotError::Decode(DecodeError::BadVersion {
                expected: SHARD_VERSION,
                found: version,
            }));
        }
        let n = d.get_u32()? as usize;
        let mut shards = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let frame = d.get_bytes()?.to_vec();
            shards.push(Snapshot::from_bytes(&frame)?);
        }
        let stored_root = d.get_u64()?;
        d.finish()?;
        let snap = Self { shards };
        if snap.root_hash() != stored_root {
            return Err(SnapshotError::DigestMismatch { which: "root" });
        }
        Ok(snap)
    }

    /// Write to a file (atomic: tmp + rename).
    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<(), SnapshotError> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, self.to_bytes())?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read + verify from a file.
    pub fn read_file(path: impl AsRef<Path>) -> Result<Self, SnapshotError> {
        let bytes = fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// Walk the declared frame lengths so a cut-off file reports
    /// `UnexpectedEof` (with the missing byte count) instead of a
    /// generic CRC failure — same contract as [`Snapshot::from_bytes`].
    /// Each iteration advances ≥ 4 bytes, so the walk is O(len) even on
    /// a hostile shard count.
    fn truncation_check(bytes: &[u8]) -> Result<(), SnapshotError> {
        const TAIL: usize = 12; // root u64 + crc u32
        let eof = |need: usize| {
            Err(SnapshotError::Decode(DecodeError::UnexpectedEof { need, have: bytes.len() }))
        };
        if bytes.len() < 12 + TAIL {
            return eof(12 + TAIL);
        }
        let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let mut need: usize = 12;
        for _ in 0..n {
            need = need.saturating_add(4);
            if bytes.len() < need.saturating_add(TAIL) {
                return eof(need.saturating_add(TAIL));
            }
            let flen = u32::from_le_bytes(bytes[need - 4..need].try_into().unwrap()) as usize;
            need = need.saturating_add(flen);
            if bytes.len() < need.saturating_add(TAIL) {
                return eof(need.saturating_add(TAIL));
            }
        }
        Ok(())
    }

    /// Whether a byte stream starts with the sharded-snapshot magic
    /// (dispatch helper for tools that accept either snapshot flavour).
    pub fn looks_sharded(bytes: &[u8]) -> bool {
        bytes.len() >= 4 && u32::from_le_bytes(bytes[..4].try_into().unwrap()) == SHARD_MAGIC
    }

    /// Compare two manifests shard-by-shard; returns the indices of
    /// diverged shards (empty = converged). The §9 convergence check for
    /// sharded deployments: a mismatch pinpoints *which* partition forked.
    pub fn diverged_shards(a: &[ShardManifestEntry], b: &[ShardManifestEntry]) -> Vec<u32> {
        let mut out = Vec::new();
        let n = a.len().max(b.len());
        for i in 0..n {
            match (a.get(i), b.get(i)) {
                (Some(x), Some(y)) if x.fnv == y.fnv && x.sha256 == y.sha256 => {}
                _ => out.push(i as u32),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::{Command, KernelConfig};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("valori_snap_test_{}_{}", std::process::id(), name));
        p
    }

    fn populated_kernel() -> Kernel {
        let mut k = Kernel::new(KernelConfig::default_q16(8));
        for i in 0..100u64 {
            let v: Vec<f32> = (0..8).map(|j| ((i * 8 + j as u64) as f32 * 0.001).sin()).collect();
            k.apply(Command::insert(i, v)).unwrap();
        }
        k.apply(Command::Delete { id: 50 }).unwrap();
        k.apply(Command::Link { from: 1, to: 2 }).unwrap();
        k
    }

    #[test]
    fn capture_restore_identical() {
        let k = populated_kernel();
        let snap = Snapshot::capture(&k);
        let k2 = snap.restore().unwrap();
        assert_eq!(k, k2);
        assert_eq!(k.state_hash(), k2.state_hash());
        assert_eq!(snap.fnv, k.state_hash());
    }

    #[test]
    fn file_roundtrip_bit_exact() {
        let k = populated_kernel();
        let snap = Snapshot::capture(&k);
        let path = tmp("file_roundtrip");
        snap.write_file(&path).unwrap();
        let snap2 = Snapshot::read_file(&path).unwrap();
        assert_eq!(snap, snap2);
        let k2 = snap2.restore().unwrap();
        assert_eq!(k.state_hash(), k2.state_hash());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn snapshot_bytes_are_deterministic() {
        let a = Snapshot::capture(&populated_kernel()).to_bytes();
        let b = Snapshot::capture(&populated_kernel()).to_bytes();
        assert_eq!(a, b);
    }

    #[test]
    fn corruption_detected_by_crc() {
        let snap = Snapshot::capture(&populated_kernel());
        let mut bytes = snap.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        assert!(matches!(Snapshot::from_bytes(&bytes), Err(SnapshotError::CrcMismatch)));
    }

    #[test]
    fn tampering_with_digest_detected() {
        // Rebuild a snapshot with a wrong fnv but a fixed-up CRC; the digest
        // check must still catch it.
        let snap = Snapshot::capture(&populated_kernel());
        let tampered = Snapshot { fnv: snap.fnv ^ 1, ..snap };
        let bytes = tampered.to_bytes(); // to_bytes recomputes a valid CRC
        assert!(matches!(
            Snapshot::from_bytes(&bytes),
            Err(SnapshotError::DigestMismatch { which: "fnv" })
        ));
    }

    #[test]
    fn truncated_file_detected() {
        let snap = Snapshot::capture(&populated_kernel());
        let bytes = snap.to_bytes();
        for cut in [0usize, 3, 10, bytes.len() - 5] {
            assert!(Snapshot::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn truncation_reports_eof_not_crc() {
        // A cut-off file is a transfer problem (retry), not corruption
        // (investigate): the length-prefix sanity check must classify it
        // as UnexpectedEof *before* the CRC ever runs.
        let snap = Snapshot::capture(&populated_kernel());
        let bytes = snap.to_bytes();
        for cut in [1usize, 12, 55, bytes.len() / 2, bytes.len() - 1] {
            match Snapshot::from_bytes(&bytes[..cut]) {
                Err(SnapshotError::Decode(DecodeError::UnexpectedEof { need, have })) => {
                    assert_eq!(have, cut);
                    assert!(need > cut, "need {need} must exceed the {cut} bytes present");
                }
                other => panic!("cut={cut}: expected UnexpectedEof, got {other:?}"),
            }
        }
        // …whereas an in-place bit flip (same length) is still CRC
        // territory.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        assert!(matches!(
            Snapshot::from_bytes(&flipped),
            Err(SnapshotError::CrcMismatch)
        ));
    }

    #[test]
    fn sharded_truncation_reports_eof_not_crc() {
        let snap = ShardedSnapshot::capture(&populated_sharded(3));
        let bytes = snap.to_bytes();
        for cut in [0usize, 11, 30, bytes.len() / 2, bytes.len() - 1] {
            match ShardedSnapshot::from_bytes(&bytes[..cut]) {
                Err(SnapshotError::Decode(DecodeError::UnexpectedEof { need, have })) => {
                    assert_eq!(have, cut);
                    assert!(need > cut, "cut={cut}");
                }
                other => panic!("cut={cut}: expected UnexpectedEof, got {other:?}"),
            }
        }
    }

    #[test]
    fn encoded_len_matches_to_bytes() {
        let snap = Snapshot::capture(&populated_kernel());
        assert_eq!(snap.encoded_len(), snap.to_bytes().len());
    }

    #[test]
    fn sha_hex_renders() {
        let snap = Snapshot::capture(&populated_kernel());
        let hex = snap.sha256_hex();
        assert_eq!(hex.len(), 64);
        assert!(hex.chars().all(|c| c.is_ascii_hexdigit()));
    }

    fn populated_sharded(n_shards: u32) -> ShardedKernel {
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(8), n_shards);
        for i in 0..80u64 {
            let v: Vec<f32> = (0..8).map(|j| ((i * 8 + j as u64) as f32 * 0.002).cos()).collect();
            sk.apply(crate::state::Command::insert(i, v)).unwrap();
        }
        sk.apply(crate::state::Command::Delete { id: 11 }).unwrap();
        sk
    }

    #[test]
    fn sharded_capture_restore_roundtrip() {
        let sk = populated_sharded(4);
        let snap = ShardedSnapshot::capture(&sk);
        assert_eq!(snap.root_hash(), sk.root_hash());
        let restored = snap.restore().unwrap();
        assert_eq!(restored, sk);
        assert_eq!(restored.root_hash(), sk.root_hash());
        // byte roundtrip too
        let snap2 = ShardedSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(snap2, snap);
        assert_eq!(snap.to_bytes(), snap2.to_bytes());
    }

    #[test]
    fn sharded_manifest_pinpoints_divergence() {
        let a = ShardedSnapshot::capture(&populated_sharded(4));
        let mut sk_b = populated_sharded(4);
        let extra = (100..u64::MAX).find(|&i| sk_b.shard_of(i) == 1).unwrap();
        sk_b.apply(crate::state::Command::insert(extra, vec![0.5; 8])).unwrap();
        let b = ShardedSnapshot::capture(&sk_b);
        assert_eq!(
            ShardedSnapshot::diverged_shards(&a.manifest(), &b.manifest()),
            vec![1]
        );
        assert_ne!(a.root_hash(), b.root_hash());
        assert!(ShardedSnapshot::diverged_shards(&a.manifest(), &a.manifest()).is_empty());
    }

    #[test]
    fn sharded_corruption_detected() {
        let snap = ShardedSnapshot::capture(&populated_sharded(2));
        let mut bytes = snap.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        assert!(ShardedSnapshot::from_bytes(&bytes).is_err());
        // and a wrong root (with fixed-up outer CRC) is caught by the
        // root digest check
        let mut tampered = snap.clone();
        tampered.shards.swap(0, 1); // shard frames out of position
        assert!(matches!(
            tampered.restore(),
            Err(SnapshotError::ShardMismatch { shard: 0, .. })
        ));
    }

    #[test]
    fn sharded_file_roundtrip() {
        let sk = populated_sharded(3);
        let snap = ShardedSnapshot::capture(&sk);
        let path = tmp("sharded_file");
        snap.write_file(&path).unwrap();
        let loaded = ShardedSnapshot::read_file(&path).unwrap();
        assert_eq!(loaded, snap);
        assert_eq!(loaded.restore().unwrap().root_hash(), sk.root_hash());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restored_kernel_continues_identically() {
        // The §8.1 scenario end-to-end (single process): snapshot on "A",
        // restore on "B", verify hashes AND identical k-NN ordering.
        let k = populated_kernel();
        let snap = Snapshot::capture(&k);
        let k2 = snap.restore().unwrap();
        let q: Vec<f32> = (0..8).map(|j| (j as f32 * 0.1).cos() * 0.5).collect();
        let h1 = k.search_f32(&q, 10).unwrap();
        let h2 = k2.search_f32(&q, 10).unwrap();
        assert_eq!(h1, h2);
    }
}
