//! E1 — Table 1: bit-level divergence of "identical" embeddings.
//!
//! The paper ran the same sentence-transformer on an x86 PC and an ARM
//! MacBook and showed the raw bits differ in every inspected dimension
//! while cosine similarity stays > 0.9999. We reproduce the *mechanism*
//! (different legal IEEE-754 evaluation orders of the same model) with the
//! env A / env B lowerings of our encoder (DESIGN §2 substitution), run
//! through the full AOT → PJRT stack.
//!
//! Fallback: when artifacts are not built, the same experiment runs on the
//! reduction-order variants in [`crate::distance::float`], which isolates
//! the identical root cause without the model.

#![forbid(unsafe_code)]

use crate::corpus::CorpusGen;
use crate::runtime::{artifacts_available, artifacts_dir, embedder::Env, Embedder, Engine};

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct Row {
    pub dimension: usize,
    pub env_a_hex: String,
    pub env_b_hex: String,
    pub differs: bool,
}

/// Full result of the divergence experiment.
#[derive(Debug, Clone)]
pub struct DivergenceResult {
    pub sentence: String,
    pub rows: Vec<Row>,
    /// Fraction of ALL dimensions whose bits differ.
    pub diverged_fraction: f64,
    /// Cosine similarity between the two embeddings.
    pub cosine: f64,
    /// Where the vectors came from.
    pub source: &'static str,
}

/// Run Table 1 against the AOT embedders (requires `make artifacts`).
pub fn run_embedder(n_rows: usize) -> crate::Result<DivergenceResult> {
    let engine = Engine::cpu()?;
    let dir = artifacts_dir();
    let ea = Embedder::load(&engine, &dir, Env::A)?;
    let eb = Embedder::load(&engine, &dir, Env::B)?;
    let sentences = CorpusGen::paper_sentences();
    let va = &ea.embed_texts(&sentences)?[0];
    let vb = &eb.embed_texts(&sentences)?[0];
    Ok(build_result(sentences[0].to_string(), va, vb, n_rows, "aot-embedder (env A vs env B)"))
}

/// Fallback: isolate the reduction-order mechanism without the model.
pub fn run_fallback(n_rows: usize) -> DivergenceResult {
    use crate::distance::float;
    use crate::hash::XorShift64;
    let mut rng = XorShift64::new(2025);
    let dim = 384; // MiniLM's true dimension, for flavour
    let basis: Vec<Vec<f32>> = (0..dim)
        .map(|_| (0..dim).map(|_| rng.next_f32_range(-0.1, 0.1)).collect())
        .collect();
    let x: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
    // "embedding" = matrix product computed two ways
    let va: Vec<f32> = basis.iter().map(|row| float::dot_f32_seq(row, &x)).collect();
    let vb: Vec<f32> = basis.iter().map(|row| float::dot_f32_fma(row, &x)).collect();
    build_result(
        "synthetic projection (seq vs fma evaluation)".to_string(),
        &va,
        &vb,
        n_rows,
        "reduction-order fallback",
    )
}

/// Run with artifacts if available, fallback otherwise.
pub fn run(n_rows: usize) -> DivergenceResult {
    if artifacts_available() {
        match run_embedder(n_rows) {
            Ok(r) => return r,
            Err(e) => eprintln!("embedder divergence failed ({e}); using fallback"),
        }
    }
    run_fallback(n_rows)
}

fn build_result(
    sentence: String,
    va: &[f32],
    vb: &[f32],
    n_rows: usize,
    source: &'static str,
) -> DivergenceResult {
    assert_eq!(va.len(), vb.len());
    let rows: Vec<Row> = va
        .iter()
        .zip(vb)
        .take(n_rows)
        .enumerate()
        .map(|(i, (a, b))| Row {
            dimension: i,
            env_a_hex: format!("0x{:08x}", a.to_bits()),
            env_b_hex: format!("0x{:08x}", b.to_bits()),
            differs: a.to_bits() != b.to_bits(),
        })
        .collect();
    let diverged =
        va.iter().zip(vb).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
    let dot: f64 = va.iter().zip(vb).map(|(a, b)| *a as f64 * *b as f64).sum();
    let na: f64 = va.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = vb.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    DivergenceResult {
        sentence,
        rows,
        diverged_fraction: diverged as f64 / va.len() as f64,
        cosine: dot / (na * nb).max(1e-12),
        source,
    }
}

/// Render in the paper's Table 1 format.
pub fn print_table(r: &DivergenceResult) {
    println!("\n=== Table 1: Bit-Level Divergence of Identical Embeddings ===");
    println!("source: {} | sentence: {:?}", r.source, r.sentence);
    println!("{:<10} {:<16} {:<16} {}", "Dimension", "Env-A (Hex)", "Env-B (Hex)", "differs");
    for row in &r.rows {
        println!(
            "{:<10} {:<16} {:<16} {}",
            row.dimension,
            row.env_a_hex,
            row.env_b_hex,
            if row.differs { "yes" } else { "no" }
        );
    }
    println!(
        "diverged dimensions: {:.1}% | cosine similarity: {:.6} (paper: differs in every \
         inspected dim, cosine > 0.9999)",
        r.diverged_fraction * 100.0,
        r.cosine
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_reproduces_paper_shape() {
        let r = run_fallback(5);
        assert_eq!(r.rows.len(), 5);
        // the paper's two claims: bits differ broadly, semantics intact
        assert!(r.diverged_fraction > 0.3, "diverged {:.2}", r.diverged_fraction);
        assert!(r.cosine > 0.9999, "cosine {}", r.cosine);
        // hex formatting
        assert!(r.rows[0].env_a_hex.starts_with("0x"));
        assert_eq!(r.rows[0].env_a_hex.len(), 10);
    }

    #[test]
    fn embedder_divergence_if_artifacts() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let r = run_embedder(5).unwrap();
        assert!(r.diverged_fraction > 0.5, "diverged {:.2}", r.diverged_fraction);
        assert!(r.cosine > 0.9999, "cosine {}", r.cosine);
    }
}
