"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops. pytest checks kernel == reference
(bit-exactly for the integer kernels, to float tolerance for attention).
"""

from __future__ import annotations

import jax.numpy as jnp

# Q16.16 constants (must match rust/src/fixed/format.rs)
Q16_FRAC_BITS = 16
Q16_SCALE = 1 << Q16_FRAC_BITS
I32_MIN = -(1 << 31)
I32_MAX = (1 << 31) - 1


def attention_ref(q, k, v, bias):
    """Masked scaled-dot-product attention.

    Args:
      q, k, v: f32[B, H, S, Dh]
      bias:    f32[B, S] additive key bias (0 for real tokens, -1e9 for pad)

    Returns:
      f32[B, H, S, Dh]
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    # scores[b, h, i, j] = q . k * scale + bias[b, j]
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) * scale
    scores = scores + bias[:, None, None, :]
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhij,bhjd->bhid", p, v)


def quantize_ref(x):
    """f32 -> Q16.16 raw int32, round-ties-even, saturating.

    Must bit-match `FixedVector::from_f32` on the Rust side (DESIGN §6):
    one correctly-rounded multiply + jnp.rint (banker's rounding) + clip.
    """
    scaled = jnp.asarray(x, jnp.float32) * jnp.float32(Q16_SCALE)
    scaled = jnp.nan_to_num(scaled, nan=0.0, posinf=float(I32_MAX), neginf=float(I32_MIN))
    r = jnp.rint(scaled)
    r = jnp.clip(r, float(I32_MIN), float(I32_MAX))
    return r.astype(jnp.int32)


def dequantize_ref(raw):
    """Q16.16 raw int32 -> f32 (observability only)."""
    return raw.astype(jnp.float32) / jnp.float32(Q16_SCALE)


def l2sq_q16_ref(query, db):
    """Integer squared-L2 distances, i64 accumulation.

    Args:
      query: int32[D]    Q16.16 raw
      db:    int32[N, D] Q16.16 raw

    Returns:
      int64[N] — wide Q32.32 distances; bit-matches rust `l2sq_q16` under
      the boundary contract (|raw| <= 2^18, D <= 16384).
    """
    q = query.astype(jnp.int64)
    d = db.astype(jnp.int64)
    diff = d - q[None, :]
    return jnp.sum(diff * diff, axis=1)


def dot_q16_ref(query, db):
    """Integer dot products, i64 accumulation. int64[N]."""
    q = query.astype(jnp.int64)
    d = db.astype(jnp.int64)
    return jnp.sum(d * q[None, :], axis=1)


def layernorm_ref(x, g, b, eps=1e-5):
    """LayerNorm over the last axis (float domain — outside the boundary)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b
