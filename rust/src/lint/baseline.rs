//! Baseline handling: grandfathered findings, diffed on every run.
//!
//! The committed `lint_baseline.json` is the *only* mutable state the
//! auditor consults. Its contract is strict in both directions:
//!
//! - a finding **not** in the baseline fails the run (new violation),
//! - a baseline entry with **no** matching finding fails the run too
//!   (stale entry — the debt was paid, delete the line so it cannot
//!   mask a future regression at the same site).
//!
//! Entries are identified by `(rule, file, key)` — never by line
//! number, so unrelated edits shifting code around cannot churn the
//! baseline. Multiple identical findings in one file are matched by
//! count (the multiset must agree exactly).

#![forbid(unsafe_code)]

use super::{Finding, Rule};
use crate::json::Json;
use std::collections::BTreeMap;

/// One grandfathered finding.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct BaselineEntry {
    pub rule: Rule,
    pub file: String,
    pub key: String,
}

/// The parsed baseline file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    /// Parse the JSON baseline format (see [`Baseline::to_json`]).
    pub fn from_json_text(text: &str) -> Result<Baseline, String> {
        let doc = crate::json::parse(text).map_err(|e| format!("baseline: {e}"))?;
        if doc.get("version").as_i64() != Some(1) {
            return Err("baseline: unsupported or missing `version` (want 1)".to_string());
        }
        let Some(items) = doc.get("entries").as_array() else {
            return Err("baseline: missing `entries` array".to_string());
        };
        let mut entries = Vec::with_capacity(items.len());
        for it in items {
            let rule = it
                .get("rule")
                .as_str()
                .and_then(Rule::from_code)
                .ok_or_else(|| format!("baseline: bad rule in {it:?}"))?;
            let file = it
                .get("file")
                .as_str()
                .ok_or_else(|| format!("baseline: missing file in {it:?}"))?
                .to_string();
            let key = it
                .get("key")
                .as_str()
                .ok_or_else(|| format!("baseline: missing key in {it:?}"))?
                .to_string();
            entries.push(BaselineEntry { rule, file, key });
        }
        Ok(Baseline { entries })
    }

    /// Serialize back to the canonical JSON format (sorted entries, so
    /// regenerating a baseline is a stable diff).
    pub fn to_json(&self) -> Json {
        let mut entries = self.entries.clone();
        entries.sort();
        Json::object(vec![
            ("version", Json::Int(1)),
            (
                "entries",
                Json::Array(
                    entries
                        .iter()
                        .map(|e| {
                            Json::object(vec![
                                ("rule", Json::str(e.rule.code())),
                                ("file", Json::str(e.file.clone())),
                                ("key", Json::str(e.key.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Build a baseline that grandfathers exactly `findings`.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        Baseline {
            entries: findings
                .iter()
                .map(|f| BaselineEntry {
                    rule: f.rule,
                    file: f.file.clone(),
                    key: f.key.clone(),
                })
                .collect(),
        }
    }
}

/// The result of diffing live findings against the baseline.
#[derive(Debug, Default)]
pub struct Diff {
    /// Findings not covered by the baseline (fail).
    pub new: Vec<Finding>,
    /// Baseline entries with no live finding left (fail: delete them).
    pub stale: Vec<BaselineEntry>,
}

impl Diff {
    pub fn is_clean(&self) -> bool {
        self.new.is_empty() && self.stale.is_empty()
    }
}

/// Multiset-diff `findings` against `baseline` by `(rule, file, key)`.
pub fn diff(findings: &[Finding], baseline: &Baseline) -> Diff {
    let mut budget: BTreeMap<(Rule, &str, &str), i64> = BTreeMap::new();
    for e in &baseline.entries {
        *budget.entry((e.rule, e.file.as_str(), e.key.as_str())).or_insert(0) += 1;
    }
    let mut out = Diff::default();
    for f in findings {
        let slot = budget.entry((f.rule, f.file.as_str(), f.key.as_str())).or_insert(0);
        if *slot > 0 {
            *slot -= 1;
        } else {
            out.new.push(f.clone());
        }
    }
    for ((rule, file, key), left) in budget {
        for _ in 0..left {
            out.stale.push(BaselineEntry {
                rule,
                file: file.to_string(),
                key: key.to_string(),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::lint::Zone;

    fn finding(rule: Rule, file: &str, key: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line: 1,
            zone: Zone::State,
            key: key.to_string(),
            message: String::new(),
        }
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let fs = [finding(Rule::R1, "state/a.rs", "f32"), finding(Rule::R5, "b.rs", "x")];
        let b = Baseline::from_findings(&fs);
        let text = b.to_json().to_string();
        let back = Baseline::from_json_text(&text).unwrap();
        let mut want = b.entries.clone();
        want.sort();
        assert_eq!(back.entries, want);
    }

    #[test]
    fn diff_matches_multisets_exactly() {
        let live = [
            finding(Rule::R1, "a.rs", "f32"),
            finding(Rule::R1, "a.rs", "f32"),
            finding(Rule::R3, "a.rs", "Instant"),
        ];
        // baseline covers one f32 and a Duration that no longer exists
        let base = Baseline {
            entries: vec![
                BaselineEntry { rule: Rule::R1, file: "a.rs".into(), key: "f32".into() },
                BaselineEntry { rule: Rule::R2, file: "a.rs".into(), key: "HashMap".into() },
            ],
        };
        let d = diff(&live, &base);
        assert_eq!(d.new.len(), 2, "{:?}", d.new); // second f32 + Instant
        assert_eq!(d.stale.len(), 1);
        assert_eq!(d.stale[0].key, "HashMap");
        assert!(!d.is_clean());
        // the exact-cover case is clean both ways
        let exact = Baseline::from_findings(&live);
        assert!(diff(&live, &exact).is_clean());
        // empty-vs-empty is clean
        assert!(diff(&[], &Baseline::default()).is_clean());
    }

    #[test]
    fn bad_baselines_are_rejected() {
        assert!(Baseline::from_json_text("{}").is_err());
        assert!(Baseline::from_json_text(r#"{"version":2,"entries":[]}"#).is_err());
        assert!(Baseline::from_json_text(
            r#"{"version":1,"entries":[{"rule":"R9","file":"x","key":"y"}]}"#
        )
        .is_err());
    }
}
