//! Bench for the **§8.2/§11 performance question**: what does fixed-point
//! determinism cost relative to hardware floats?
//!
//! Paper: "Software-based fixed-point arithmetic is slower than
//! hardware-accelerated float ops" (§11) but "no_std optimizations keep
//! latency low" (§8.2). This bench quantifies the dot/L2 kernel overhead
//! across dimensions, plus the XLA-offloaded integer distance path (E9).
//!
//! Run: `cargo bench --bench fixed_vs_float`

use valori::bench::{bench, BenchConfig, Report};
use valori::distance::{dot_q16, float, l2sq_q16};
use valori::hash::XorShift64;

fn main() {
    let cfg = if std::env::var("VALORI_BENCH_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let mut rng = XorShift64::new(11);

    for dim in [128usize, 384, 1024] {
        let af: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let bf: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let aq: Vec<i32> = af.iter().map(|&x| (x * 65536.0) as i32).collect();
        let bq: Vec<i32> = bf.iter().map(|&x| (x * 65536.0) as i32).collect();

        let mut report = Report::new(format!("dot/L2 kernels, dim {dim}"));
        let s_f32 = bench(&cfg, || float::dot_f32_seq(&af, &bf));
        let s_q16 = bench(&cfg, || dot_q16(&aq, &bq));
        let ratio = s_q16.mean_ns / s_f32.mean_ns;
        report.add("dot f32 (scalar seq)", s_f32);
        report.add("dot f32 (fma)", bench(&cfg, || float::dot_f32_fma(&af, &bf)));
        report.add("dot Q16.16 (i64 acc)", s_q16);
        report.add("l2  f32 (scalar seq)", bench(&cfg, || float::l2sq_f32_seq(&af, &bf)));
        report.add("l2  Q16.16 (i64 acc)", bench(&cfg, || l2sq_q16(&aq, &bq)));
        report.note(format!(
            "fixed/float dot overhead: {ratio:.2}x (paper §11 predicts >1; integer SIMD keeps it small)"
        ));
        report.print();
    }

    // Batched distances through the AOT Pallas/XLA path (the offload the
    // kernel can use for large scans) vs native Rust loops.
    if valori::runtime::artifacts_available() {
        let dir = valori::runtime::artifacts_dir();
        let m = valori::runtime::Manifest::load(&dir).expect("manifest");
        let engine = valori::runtime::Engine::cpu().expect("pjrt");
        let de = valori::runtime::DistanceEngine::load(&engine, &dir, m.model.d_model, m.model.db_rows)
            .expect("distance engine");
        let dim = m.model.d_model;
        let n = m.model.db_rows;
        let db: Vec<i32> = (0..n * dim).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();
        let q: Vec<i32> = (0..dim).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();

        let mut report = Report::new(format!("batched L2 distances, {n} × dim-{dim}"));
        report.add("rust loop (i64 acc)", bench(&cfg, || {
            (0..n).map(|r| l2sq_q16(&q, &db[r * dim..(r + 1) * dim])).collect::<Vec<_>>()
        }));
        report.add("XLA/Pallas AOT (i64 acc)", bench(&BenchConfig::quick(), || {
            de.l2sq_q16(&q, &db).unwrap()
        }));
        report.note("bit-identical outputs (verified in rust/tests/cross_impl.rs)");
        report.print();
    } else {
        println!("\n(artifacts not built — skipping the XLA distance comparison)");
    }
}
