//! Verifiable state receipts (paper §8, PR-10).
//!
//! The flat per-shard FNV fold ([`crate::snapshot`]) can say *that* two
//! replicas diverged but never *where*, and gives an auditor nothing they
//! can check without the full state. This module turns the state root into
//! a proof system:
//!
//! - [`tree`] — a deterministic binary Merkle tree over per-slot digests.
//!   The tree shape is a pure function of the arena capacity (slots are
//!   padded to the next power of two with a fixed empty-leaf sentinel), so
//!   two kernels that applied the same command log have bit-identical
//!   trees. The kernel maintains it **incrementally**: every applied
//!   command recomputes only the O(log n) root path of the slots it dirtied
//!   ([`crate::state::Kernel`]), never a full rebuild.
//! - [`leaf`] — the canonical leaf encoding
//!   `id ‖ vector bytes ‖ meta ‖ links` (all fixed-width little-endian, meta
//!   sorted by key, links ascending). A leaf is self-describing: the same
//!   bytes that hash into the tree are shipped for divergence repair.
//! - [`receipt`] — the signed-shape receipt
//!   `{state_version, seq, snapshot_hash, wal_hash, merkle_root}` returned
//!   by `GET /v2/collections/{name}/proof`, the per-record
//!   [`MembershipProof`], and the offline verifier shared by
//!   `valori verify` and the test suite.
//!
//! Determinism discipline: the tree is **derived state** — it is never
//! serialized (snapshots stay byte-identical) and is rebuilt on decode,
//! exactly like the SQ8 code arena. This module is a *state* zone in the
//! `valori lint` zone map: integer-only, no clocks, no randomness.

#![forbid(unsafe_code)]

pub mod leaf;
pub mod receipt;
pub mod tree;

pub use leaf::{LeafBody, LeafError, LeafRecord};
pub use receipt::{verify_membership, verify_receipt, MembershipProof, Receipt, VerifyError};
pub use tree::{combined_root, fold_path, leaf_hash, node_hash, MerkleTree};
