//! Integration: WAL durability + audit replay (paper §9), with failure
//! injection (torn writes, bit rot, truncation at every boundary).

use valori::state::{CanonCommand, Command, Kernel, KernelConfig};
use valori::wal::{self, WalWriter};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("valori_it_wal_{}_{name}", std::process::id()))
}

fn workload(kernel: &mut Kernel, wal: &mut WalWriter, n: usize) {
    for i in 0..n as u64 {
        let v: Vec<f32> = (0..kernel.config().dim)
            .map(|j| ((i * 13 + j as u64) as f32 * 0.011).sin() * 0.8)
            .collect();
        let seq = kernel.seq();
        let canon = kernel.apply(Command::insert(i, v)).unwrap();
        wal.append(seq, &canon).unwrap();
        if i % 9 == 4 {
            let seq = kernel.seq();
            let canon = kernel.apply(Command::Delete { id: i / 2 }).unwrap();
            wal.append(seq, &canon).unwrap();
        }
    }
    wal.sync().unwrap();
}

#[test]
fn replay_reproduces_hash_after_mixed_workload() {
    let path = tmp("mixed");
    let mut live = Kernel::new(KernelConfig::default_q16(8));
    {
        let mut wal = WalWriter::create(&path).unwrap();
        workload(&mut live, &mut wal, 150);
    }
    let rec = wal::recover(&path).unwrap();
    assert!(!rec.truncated_tail);
    let mut replayed = Kernel::new(KernelConfig::default_q16(8));
    wal::replay(&mut replayed, &rec.entries).unwrap();
    assert_eq!(replayed.state_hash(), live.state_hash());
    assert_eq!(replayed.seq(), live.seq());
    std::fs::remove_file(&path).ok();
}

#[test]
fn torn_write_at_every_cut_point_recovers_a_prefix() {
    // Build a small WAL, then truncate at EVERY byte offset: recovery must
    // never panic, never mis-parse, and always return a valid prefix.
    let path = tmp("cuts");
    let mut live = Kernel::new(KernelConfig::default_q16(4));
    {
        let mut wal = WalWriter::create(&path).unwrap();
        for i in 0..10u64 {
            let seq = live.seq();
            let canon = live.apply(Command::insert(i, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    let bytes = std::fs::read(&path).unwrap();
    let mut prefix_lens = std::collections::BTreeSet::new();
    for cut in 0..=bytes.len() {
        let rec = wal::recover_bytes(&bytes[..cut]).unwrap();
        assert!(rec.entries.len() <= 10);
        // a cut strictly inside the log implies a shorter prefix
        if cut < bytes.len() {
            assert!(rec.entries.len() < 10 || rec.valid_bytes as usize <= cut);
        }
        prefix_lens.insert(rec.entries.len());
        // every recovered prefix replays cleanly
        let mut k = Kernel::new(KernelConfig::default_q16(4));
        wal::replay(&mut k, &rec.entries).unwrap();
        assert_eq!(k.seq(), rec.entries.len() as u64);
    }
    // all prefix lengths 0..=10 appear across the cuts
    assert_eq!(prefix_lens.len(), 11);
    std::fs::remove_file(&path).ok();
}

#[test]
fn bit_rot_in_middle_is_fatal_loudly() {
    let path = tmp("rot");
    let mut live = Kernel::new(KernelConfig::default_q16(4));
    {
        let mut wal = WalWriter::create(&path).unwrap();
        for i in 0..20u64 {
            let seq = live.seq();
            let canon = live.apply(Command::insert(i, vec![0.5, 0.5, 0.5, 0.5])).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    let mut bytes = std::fs::read(&path).unwrap();
    let quarter = bytes.len() / 4;
    bytes[quarter] ^= 0x10;
    match wal::recover_bytes(&bytes) {
        Err(wal::WalError::MidLogCorruption { offset, .. }) => {
            assert!((offset as usize) <= quarter);
        }
        other => panic!("expected MidLogCorruption, got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn append_after_recovery_continues_sequence() {
    let path = tmp("resume");
    let mut live = Kernel::new(KernelConfig::default_q16(4));
    {
        let mut wal = WalWriter::create(&path).unwrap();
        for i in 0..5u64 {
            let seq = live.seq();
            let canon = live.apply(Command::insert(i, vec![0.1; 4])).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    // restart: recover, then append more
    let rec = wal::recover(&path).unwrap();
    let mut restarted = Kernel::new(KernelConfig::default_q16(4));
    wal::replay(&mut restarted, &rec.entries).unwrap();
    {
        let mut wal = WalWriter::append_to(&path, rec.entries.len() as u64).unwrap();
        for i in 5..10u64 {
            let seq = restarted.seq();
            let canon = restarted.apply(Command::insert(i, vec![0.2; 4])).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    // final replay sees the full history with monotone seq
    let rec = wal::recover(&path).unwrap();
    assert_eq!(rec.entries.len(), 10);
    for (i, e) in rec.entries.iter().enumerate() {
        assert_eq!(e.seq, i as u64);
    }
    let mut fresh = Kernel::new(KernelConfig::default_q16(4));
    wal::replay(&mut fresh, &rec.entries).unwrap();
    assert_eq!(fresh.state_hash(), restarted.state_hash());
    std::fs::remove_file(&path).ok();
}

#[test]
fn replay_rejects_inconsistent_log() {
    // A log whose commands don't type-check against the state (e.g. a
    // delete of a never-inserted id) must fail loudly, not silently skip.
    let entries = vec![
        wal::WalEntry { seq: 0, command: CanonCommand::Insert { id: 1, raw: vec![0; 4] } },
        wal::WalEntry { seq: 1, command: CanonCommand::Delete { id: 42 } },
    ];
    let mut k = Kernel::new(KernelConfig::default_q16(4));
    assert!(wal::replay(&mut k, &entries).is_err());
    assert_eq!(k.seq(), 1, "replay must stop at the failing command");
}

#[test]
fn wal_bytes_are_deterministic() {
    // Two identical runs produce byte-identical WAL files (the log itself
    // is part of the auditable artifact).
    let p1 = tmp("det1");
    let p2 = tmp("det2");
    for p in [&p1, &p2] {
        let mut k = Kernel::new(KernelConfig::default_q16(4));
        let mut wal = WalWriter::create(p).unwrap();
        for i in 0..25u64 {
            let seq = k.seq();
            let canon =
                k.apply(Command::insert(i, vec![0.3, -0.3, 0.6, -0.6])).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}
