#!/usr/bin/env python3
"""Generate `sq8_recall_golden.json`: an independent (Python) mirror of
the SQ8 quantized scan tier, pinning recall@10 of the two-phase search
against the exact Q16.16 top-k on a fixed splitmix64 corpus.

The mirror re-implements, from the documented contracts only:

  * the bench/test corpus generator (splitmix64 stream, % 131072 - 65536,
    so |raw| <= 2^16, inside the boundary contract's |raw| <= 2^18);
  * the integer-only SQ8 encode: code = clamp(round_half_away_from_zero(
    raw * 127 / 2^18), -127, 127) — pure integer arithmetic, no floats;
  * phase 1: i8 L2 scan, select k * overscan candidates under the total
    order (approx_dist, id) ascending;
  * phase 2: exact Q16.16 L2 re-rank of those candidates under
    (dist, id) ascending, truncate to k.

`tests/quant_equivalence.rs::recall_matches_python_mirror_fixture` runs
the same workload through the production Rust kernels and asserts the
per-query overlap counts (and the pinned exact top-10 id lists) match
this fixture bit for bit. Regenerate with:

    python3 rust/tests/fixtures/make_sq8_recall.py
"""

import json
import pathlib

M64 = (1 << 64) - 1

N = 2000
DIM = 32
K = 10
SEED = 0x53513852  # "SQ8R"
QUERY_SEED_XOR = 0x5155455259  # the bench suite's disjoint query stream
QUERIES = 16
OVERSCANS = [1, 2, 4, 8]
QUANT_BOUND_RAW = 1 << 18  # boundary contract: max_abs 4.0 => |raw| <= 2^18


def splitmix64(z):
    z = (z + 0x9E3779B97F4A7C15) & M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return z ^ (z >> 31)


def raw_component(seed, index):
    return (splitmix64(seed ^ index) % 131072) - 65536


def raw_row(seed, i, dim):
    return [raw_component(seed, i * dim + j) for j in range(dim)]


def encode_component(raw):
    # Round half away from zero with truncating integer division, exactly
    # as the Rust encoder does (|raw * 127| <= 2^25, exact in i64).
    num = raw * 127
    rounded = (abs(num) + QUANT_BOUND_RAW // 2) // QUANT_BOUND_RAW
    if num < 0:
        rounded = -rounded
    return max(-127, min(127, rounded))


def l2_exact(a, b):
    return sum((x - y) ** 2 for x, y in zip(a, b))


def l2_sq8(a, b):
    return sum((x - y) ** 2 for x, y in zip(a, b))


def exact_topk(corpus, q, k):
    hits = sorted((l2_exact(q, v), i) for i, v in enumerate(corpus))
    return [i for _, i in hits[:k]]


def two_phase(corpus, codes, q, qcodes, k, overscan):
    approx = sorted((l2_sq8(qcodes, c), i) for i, c in enumerate(codes))
    candidates = [i for _, i in approx[: k * overscan]]
    exact = sorted((l2_exact(q, corpus[i]), i) for i in candidates)
    return [i for _, i in exact[:k]]


def main():
    corpus = [raw_row(SEED, i, DIM) for i in range(N)]
    codes = [[encode_component(x) for x in row] for row in corpus]
    queries = [raw_row(SEED ^ QUERY_SEED_XOR, i, DIM) for i in range(QUERIES)]

    exact = [exact_topk(corpus, q, K) for q in queries]
    recall = {}
    for overscan in OVERSCANS:
        counts = []
        for qi, q in enumerate(queries):
            qcodes = [encode_component(x) for x in q]
            got = two_phase(corpus, codes, q, qcodes, K, overscan)
            counts.append(len(set(got) & set(exact[qi])))
        recall[str(overscan)] = counts

    doc = {
        "comment": "SQ8 two-phase recall@10 vs exact Q16.16 top-k, from an "
        "independent Python mirror (make_sq8_recall.py). Counts are "
        "|two_phase_ids ∩ exact_top10| per query; exact_top10 pins the "
        "(dist, id) total order for the first three queries.",
        "n": N,
        "dim": DIM,
        "k": K,
        "seed": SEED,
        "query_seed_xor": QUERY_SEED_XOR,
        "queries": QUERIES,
        "metric": "l2",
        "quant_bound_raw": QUANT_BOUND_RAW,
        "exact_top10": exact[:3],
        "recall_at_10": recall,
    }
    out = pathlib.Path(__file__).with_name("sq8_recall_golden.json")
    out.write_text(json.dumps(doc, indent=1, ensure_ascii=False) + "\n")
    total = {o: sum(c) for o, c in recall.items()}
    print(f"wrote {out}")
    for o in OVERSCANS:
        print(f"  overscan {o}: mean recall@10 = {total[str(o)] / (10 * QUERIES):.3f}")


if __name__ == "__main__":
    main()
