//! E5 — §8.2 raw retrieval latency.
//!
//! Paper claim: "raw retrieval latency is < 500 µs for typical k-NN
//! queries" on a MacBook M3 at ~10k vectors. We measure the same workload
//! (10k × dim-128, k=10) on this host for the Q16.16 HNSW, the f32 HNSW
//! and the flat scans, with the in-crate bench harness.

#![forbid(unsafe_code)]

use crate::bench::{bench, BenchConfig, Report, Stats};
use crate::distance::Metric;
use crate::experiments::synthetic_embeddings;
use crate::fixed::{FixedFormat, Q16_16};
use crate::index::{FlatIndex, Hnsw, HnswParams, VectorIndex};

/// Latency experiment result.
#[derive(Debug, Clone)]
pub struct LatencyResult {
    pub n: usize,
    pub dim: usize,
    pub k: usize,
    pub hnsw_q16: Stats,
    pub hnsw_f32: Stats,
    pub flat_q16: Stats,
    pub flat_f32: Stats,
    /// The paper's headline check.
    pub q16_p50_under_500us: bool,
}

/// Build the four indices and measure query latency.
pub fn run(n: usize, dim: usize, k: usize, cfg: &BenchConfig) -> LatencyResult {
    let embeddings = synthetic_embeddings(n, dim, 32, 4242);
    let queries = synthetic_embeddings(64, dim, 32, 999);

    let params = HnswParams::default();
    let mut h_q16: Hnsw<i32> = Hnsw::new(dim, Metric::L2, params);
    let mut h_f32: Hnsw<f32> = Hnsw::new(dim, Metric::L2, params);
    let mut f_q16: FlatIndex<i32> = FlatIndex::new(dim, Metric::L2);
    let mut f_f32: FlatIndex<f32> = FlatIndex::new(dim, Metric::L2);
    for (id, v) in embeddings.iter().enumerate() {
        let raw: Vec<i32> = v.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
        h_q16.insert(id as u64, raw.clone());
        h_f32.insert(id as u64, v.clone());
        f_q16.insert(id as u64, raw);
        f_f32.insert(id as u64, v.clone());
    }
    let raw_queries: Vec<Vec<i32>> = queries
        .iter()
        .map(|q| q.iter().map(|&x| Q16_16::quantize(x as f64)).collect())
        .collect();

    let mut qi = 0usize;
    let hnsw_q16 = bench(cfg, || {
        qi = (qi + 1) % raw_queries.len();
        h_q16.search(&raw_queries[qi], k)
    });
    let mut qi = 0usize;
    let hnsw_f32 = bench(cfg, || {
        qi = (qi + 1) % queries.len();
        h_f32.search(&queries[qi], k)
    });
    let mut qi = 0usize;
    let flat_q16 = bench(cfg, || {
        qi = (qi + 1) % raw_queries.len();
        f_q16.search(&raw_queries[qi], k)
    });
    let mut qi = 0usize;
    let flat_f32 = bench(cfg, || {
        qi = (qi + 1) % queries.len();
        f_f32.search(&queries[qi], k)
    });

    LatencyResult {
        n,
        dim,
        k,
        q16_p50_under_500us: hnsw_q16.p50_ns < 500_000.0,
        hnsw_q16,
        hnsw_f32,
        flat_q16,
        flat_f32,
    }
}

/// Render the §8.2 result.
pub fn print_result(r: &LatencyResult) {
    let mut report = Report::new(format!(
        "§8.2 k-NN latency — {} vectors × dim {}, k={}",
        r.n, r.dim, r.k
    ));
    report.add("valori Q16.16 HNSW", r.hnsw_q16);
    report.add("baseline f32 HNSW", r.hnsw_f32);
    report.add("valori Q16.16 flat", r.flat_q16);
    report.add("baseline f32 flat", r.flat_f32);
    report.note(format!(
        "paper claim: < 500 µs typical k-NN (M3). Q16.16 HNSW p50 under 500 µs: {}",
        r.q16_p50_under_500us
    ));
    report.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_experiment_runs_small() {
        let r = run(500, 32, 10, &BenchConfig::quick());
        assert!(r.hnsw_q16.iters >= 5);
        // HNSW must beat flat scan even at this small scale... not
        // guaranteed at n=500; just check everything produced numbers.
        assert!(r.flat_f32.mean_ns > 0.0);
        assert!(r.hnsw_f32.mean_ns > 0.0);
    }

    #[test]
    fn paper_headline_at_scale() {
        // the real §8.2 shape at 10k/128 runs in benches; here a reduced
        // 2k/64 version still demonstrates sub-500µs HNSW behaviour.
        let r = run(2000, 64, 10, &BenchConfig::quick());
        assert!(
            r.hnsw_q16.p50_ns < 500_000.0,
            "Q16.16 HNSW p50 = {} ns",
            r.hnsw_q16.p50_ns
        );
    }
}
