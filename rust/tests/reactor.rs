//! Integration: the epoll reactor front end's edge cases over real
//! sockets — split reads, size-limit boundaries, slow-loris eviction,
//! pipelining rejection, the connection cap and keep-alive reuse.

#![cfg(target_os = "linux")]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use valori::http::{
    client, Handler, MAX_BODY, MAX_HEADER, Request, Response, Server, ServerConfig, ServerMetrics,
};

fn echo_handler() -> Handler {
    Arc::new(|req: Request| {
        if req.path == "/echo" {
            let mut resp = Response::text(200, String::new());
            resp.body = req.body;
            resp
        } else {
            Response::not_found()
        }
    })
}

/// Read one HTTP response (status, body) off a buffered socket.
fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, Vec<u8>)> {
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| std::io::Error::other(format!("bad status line {status_line:?}")))?;
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let t = line.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[test]
fn default_front_end_is_the_reactor() {
    let server = Server::start("127.0.0.1:0", 2, echo_handler()).unwrap();
    assert_eq!(server.backend_name(), "epoll");
    server.stop();
}

#[test]
fn request_split_across_many_tiny_writes() {
    let server = Server::start("127.0.0.1:0", 2, echo_handler()).unwrap();
    let raw = b"POST /echo HTTP/1.1\r\nhost: x\r\ncontent-length: 11\r\n\r\nsplit-hello";
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    // One byte per write for the head, tiny pauses so the reactor sees
    // many distinct readiness edges mid-request.
    for &b in raw.iter() {
        stream.write_all(&[b]).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_micros(300));
    }
    let mut reader = BufReader::new(stream);
    let (status, body) = read_response(&mut reader).unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, b"split-hello");
    server.stop();
}

#[test]
fn header_exactly_at_max_header_accepted_one_more_rejected() {
    let server = Server::start("127.0.0.1:0", 2, echo_handler()).unwrap();
    // The header section (everything after the request line, including
    // the terminating blank line) carries the cap.
    let overhead = "x-f: \r\n".len() + "\r\n".len();

    // exactly MAX_HEADER -> served
    let pad = "p".repeat(MAX_HEADER - overhead);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(stream, "POST /echo HTTP/1.1\r\nx-f: {pad}\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _) = read_response(&mut reader).unwrap();
    assert_eq!(status, 200);

    // one byte over -> 413 and the connection closes
    let pad = "p".repeat(MAX_HEADER - overhead + 1);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(stream, "POST /echo HTTP/1.1\r\nx-f: {pad}\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _) = read_response(&mut reader).unwrap();
    assert_eq!(status, 413);
    server.stop();
}

#[test]
fn body_exactly_at_max_body_accepted_one_more_rejected() {
    let server = Server::start("127.0.0.1:0", 2, echo_handler()).unwrap();

    // exactly MAX_BODY -> echoed back whole
    let body = vec![0x42u8; MAX_BODY];
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(stream, "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n", body.len()).unwrap();
    stream.write_all(&body).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, echoed) = read_response(&mut reader).unwrap();
    assert_eq!(status, 200);
    assert_eq!(echoed.len(), MAX_BODY);
    assert!(echoed == body, "MAX_BODY echo must round-trip bit-exact");

    // one byte over is rejected at the header, before any body bytes
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(stream, "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _) = read_response(&mut reader).unwrap();
    assert_eq!(status, 413);
    server.stop();
}

#[test]
fn slow_loris_is_evicted_by_the_timer_wheel() {
    let metrics = Arc::new(ServerMetrics::default());
    let config = ServerConfig {
        workers: 2,
        read_timeout: Duration::from_millis(300),
        metrics: Arc::clone(&metrics),
        ..Default::default()
    };
    let server = Server::start_with("127.0.0.1:0", config, echo_handler()).unwrap();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // half a request line, then a trickle that never completes it
    stream.write_all(b"GET /ech").unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(200));
    let _ = stream.write_all(b"o");
    let _ = stream.flush();
    // the deadline counts from request start, so the trickle cannot
    // extend it: within ~2x the timeout the server must close on us
    let mut buf = [0u8; 64];
    let n = stream.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "slow-loris connection must be closed without a response");
    assert!(
        ServerMetrics::get(&metrics.connections_timed_out) >= 1,
        "timeout eviction must be counted"
    );
    server.stop();
}

#[test]
fn pipelined_requests_are_rejected() {
    let server = Server::start("127.0.0.1:0", 2, echo_handler()).unwrap();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // two complete requests in one segment: the first is served, the
    // second is refused with 400 and the connection closes
    let two = b"POST /echo HTTP/1.1\r\ncontent-length: 3\r\n\r\nonePOST /echo HTTP/1.1\r\ncontent-length: 3\r\n\r\ntwo";
    stream.write_all(two).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, body) = read_response(&mut reader).unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, b"one");
    let (status, body) = read_response(&mut reader).unwrap();
    assert_eq!(status, 400);
    assert!(
        String::from_utf8_lossy(&body).contains("pipelining"),
        "rejection must say why: {body:?}"
    );
    let metrics = Arc::clone(server.metrics());
    assert!(ServerMetrics::get(&metrics.pipelined_rejected) >= 1);
    server.stop();
}

#[test]
fn connection_cap_rejects_with_503() {
    let config = ServerConfig { workers: 2, max_connections: 4, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", config, echo_handler()).unwrap();
    let addr = server.addr();
    // fill the table with 4 live keep-alive connections
    let mut held: Vec<client::Connection> = Vec::new();
    for _ in 0..4 {
        let mut c = client::Connection::connect(&addr).unwrap();
        let (status, _) = c.request("POST", "/echo", b"hold").unwrap();
        assert_eq!(status, 200);
        held.push(c);
    }
    // the next connection must be turned away
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _) = read_response(&mut reader).unwrap();
    assert_eq!(status, 503);
    drop(held);
    server.stop();
}

#[test]
fn over_cap_rejection_counts_rejected_not_accepted() {
    let config = ServerConfig { workers: 2, max_connections: 4, ..Default::default() };
    let metrics = Arc::clone(&config.metrics);
    let server = Server::start_with("127.0.0.1:0", config, echo_handler()).unwrap();
    let addr = server.addr();
    let mut held: Vec<client::Connection> = Vec::new();
    for _ in 0..4 {
        let mut c = client::Connection::connect(&addr).unwrap();
        let (status, _) = c.request("POST", "/echo", b"hold").unwrap();
        assert_eq!(status, 200);
        held.push(c);
    }
    // Two over-cap arrivals: one reads promptly, one drags its feet.
    // Both must receive the complete 503 and then EOF — the rejection is
    // delivered through the nonblocking write path by a short-lived
    // loop-owned connection, not a blocking write on the event loop.
    let prompt = TcpStream::connect(addr).unwrap();
    prompt.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let slow = TcpStream::connect(addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    for stream in [prompt, slow] {
        let mut reader = BufReader::new(stream);
        let (status, _) = read_response(&mut reader).unwrap();
        assert_eq!(status, 503);
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "over-cap rejection must close the connection");
    }
    // The bug this pins: rejections used to increment
    // `connections_accepted`, silently shrinking the effective cap and
    // corrupting the accept/reject accounting.
    assert_eq!(ServerMetrics::get(&metrics.connections_accepted), 4);
    assert!(ServerMetrics::get(&metrics.connections_rejected) >= 2);
    // The rejection slots drain back out of the open gauge (bounded
    // wait: drop_conn runs just after the fd close we observed as EOF).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while ServerMetrics::get(&metrics.connections_open) != 4 {
        assert!(std::time::Instant::now() < deadline, "open gauge stuck");
        std::thread::sleep(Duration::from_millis(10));
    }
    // ...and the cap still admits exactly as many as configured: closing
    // one held connection frees a slot for a fresh client.
    drop(held.pop());
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let mut c = client::Connection::connect(&addr).unwrap();
        match c.request("POST", "/echo", b"fresh") {
            Ok((200, body)) => {
                assert_eq!(body, b"fresh");
                break;
            }
            _ => {
                // the reactor may not have reaped the closed conn yet
                assert!(std::time::Instant::now() < deadline, "freed slot never reusable");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    drop(held);
    server.stop();
}

#[test]
fn keep_alive_request_cap_closes_then_client_reconnects() {
    let config = ServerConfig { workers: 2, max_requests_per_conn: 5, ..Default::default() };
    let metrics = Arc::clone(&config.metrics);
    let server = Server::start_with("127.0.0.1:0", config, echo_handler()).unwrap();
    let mut conn = client::Connection::connect(&server.addr()).unwrap();
    for i in 0..12 {
        let msg = format!("r{i}");
        let (status, body) = conn.request("POST", "/echo", msg.as_bytes()).unwrap();
        assert_eq!(status, 200, "request {i}");
        assert_eq!(body, msg.as_bytes());
    }
    // 12 requests at 5 per connection = at least 3 connections
    assert!(ServerMetrics::get(&metrics.connections_accepted) >= 3);
    assert_eq!(ServerMetrics::get(&metrics.requests_served), 12);
    server.stop();
}
