//! Weight/parameter manifest reader (`artifacts/manifest.json`).
//!
//! aot.py writes the HLO parameter order, shapes and dtypes plus the model
//! constants; this module parses it with the in-crate JSON parser and loads
//! the little-endian weight binaries.

#![forbid(unsafe_code)]

use crate::json::{parse, Json};
use crate::Error;
use std::fs;
use std::path::Path;

/// One HLO parameter (a weight tensor).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model constants shared with python/compile/model.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub pad_id: i32,
    pub db_rows: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub params: Vec<ParamSpec>,
    pub model: ModelDims,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let path = artifacts_dir.as_ref().join("manifest.json");
        let text = fs::read_to_string(&path)?;
        let json =
            parse(&text).map_err(|e| Error::Runtime(format!("manifest {path:?}: {e}")))?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> crate::Result<Self> {
        let err = |what: &str| Error::Runtime(format!("manifest missing/invalid: {what}"));
        let params_json = json.get("params").as_array().ok_or_else(|| err("params"))?;
        let mut params = Vec::with_capacity(params_json.len());
        for p in params_json {
            let name = p.get("name").as_str().ok_or_else(|| err("param name"))?.to_string();
            let shape = p
                .get("shape")
                .as_array()
                .ok_or_else(|| err("param shape"))?
                .iter()
                .map(|v| v.as_u64().map(|x| x as usize))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| err("param shape entry"))?;
            let dtype = p.get("dtype").as_str().ok_or_else(|| err("param dtype"))?.to_string();
            params.push(ParamSpec { name, shape, dtype });
        }
        let m = json.get("model");
        let get = |k: &str| m.get(k).as_u64().map(|v| v as usize);
        let model = ModelDims {
            vocab: get("vocab").ok_or_else(|| err("vocab"))?,
            d_model: get("d_model").ok_or_else(|| err("d_model"))?,
            n_heads: get("n_heads").ok_or_else(|| err("n_heads"))?,
            n_layers: get("n_layers").ok_or_else(|| err("n_layers"))?,
            d_ff: get("d_ff").ok_or_else(|| err("d_ff"))?,
            seq_len: get("seq_len").ok_or_else(|| err("seq_len"))?,
            batch: get("batch").ok_or_else(|| err("batch"))?,
            pad_id: m.get("pad_id").as_i64().ok_or_else(|| err("pad_id"))? as i32,
            db_rows: get("db_rows").ok_or_else(|| err("db_rows"))?,
        };
        Ok(Self { params, model })
    }

    /// Read one weight binary (little-endian f32) and verify its size.
    pub fn load_weight(
        &self,
        artifacts_dir: impl AsRef<Path>,
        spec: &ParamSpec,
    ) -> crate::Result<Vec<f32>> {
        let path = artifacts_dir.as_ref().join("weights").join(format!("{}.bin", spec.name));
        let bytes = fs::read(&path)?;
        let expected = spec.element_count() * 4;
        if bytes.len() != expected {
            return Err(Error::Runtime(format!(
                "weight {}: expected {expected} bytes, found {}",
                spec.name,
                bytes.len()
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "params": [
        {"name": "tok_emb", "shape": [8, 4], "dtype": "f32"},
        {"name": "lnf_g", "shape": [4], "dtype": "f32"}
      ],
      "model": {"vocab": 8, "d_model": 4, "n_heads": 2, "n_layers": 1,
                "d_ff": 8, "seq_len": 4, "batch": 2, "pad_id": 0, "db_rows": 16}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.params[0].shape, vec![8, 4]);
        assert_eq!(m.params[0].element_count(), 32);
        assert_eq!(m.model.d_model, 4);
        assert_eq!(m.model.pad_id, 0);
    }

    #[test]
    fn missing_field_is_error() {
        let bad = r#"{"params": [], "model": {"vocab": 8}}"#;
        assert!(Manifest::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn weight_size_mismatch_is_error() {
        let dir = std::env::temp_dir().join(format!("valori_manifest_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("weights")).unwrap();
        std::fs::write(dir.join("weights/lnf_g.bin"), [0u8; 12]).unwrap(); // 3 floats, want 4
        let m = Manifest::from_json(&parse(SAMPLE).unwrap()).unwrap();
        let err = m.load_weight(&dir, &m.params[1]).unwrap_err();
        assert!(matches!(err, Error::Runtime(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_if_built() {
        // Exercises the real artifact when `make artifacts` has run.
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.params.len(), 16);
        assert_eq!(m.model.d_model, 128);
        let w = m.load_weight(&dir, &m.params[0]).unwrap();
        assert_eq!(w.len(), m.params[0].element_count());
    }
}
