//! The front-end equivalence proof (ISSUE 3 acceptance criteria):
//!
//! 1. The blocking thread-per-connection path (`Server::start_blocking`,
//!    kept as the reference implementation) and the epoll reactor
//!    (`Server::start`, the default) produce **byte-identical** response
//!    streams for identical request streams — success paths, error
//!    paths, keep-alive headers and all.
//! 2. 256 concurrent keep-alive clients each issuing 50 sequential
//!    requests receive responses bit-identical to a single sequential
//!    client, and the kernel's root hash is identical to a sequential
//!    run's — the reactor orders nothing that reaches the kernel.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;
use valori::http::{client, Handler, MAX_BODY, Server};
use valori::json::Json;
use valori::node::{route, serve, NodeConfig, NodeState};
use valori::state::{Command, Kernel, KernelConfig, ShardedKernel};

fn node_state(dim: usize, shards: u32) -> Arc<NodeState> {
    let kernel = ShardedKernel::new(KernelConfig::default_q16(dim), shards);
    Arc::new(NodeState::new_sharded(kernel, &NodeConfig::default(), None).unwrap())
}

fn node_handler(state: Arc<NodeState>) -> Handler {
    Arc::new(move |req| route(&state, req))
}

/// Read one full raw response (status line + headers + body) and return
/// its exact bytes.
fn read_raw_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<Vec<u8>> {
    let mut raw = Vec::new();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Err(std::io::Error::other("eof before response end"));
        }
        raw.extend_from_slice(line.as_bytes());
        let t = line.trim_end();
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
        if t.is_empty() && raw.len() > 2 {
            break; // blank line terminates the header section
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    raw.extend_from_slice(&body);
    Ok(raw)
}

/// Send each raw request over one keep-alive socket and concatenate the
/// exact response bytes.
fn raw_exchange(addr: &SocketAddr, requests: &[Vec<u8>]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut captured = Vec::new();
    for req in requests {
        stream.write_all(req).unwrap();
        stream.flush().unwrap();
        captured.extend_from_slice(&read_raw_response(&mut reader).unwrap());
    }
    captured
}

fn raw_request(method: &str, target: &str, body: &str) -> Vec<u8> {
    format!("{method} {target} HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}", body.len())
        .into_bytes()
}

/// Send partial request bytes, half-close the write side, and collect
/// whatever the server puts on the wire until it closes.
fn truncated_exchange(addr: &SocketAddr, partial: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(partial).unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out);
    out
}

#[test]
fn blocking_and_reactor_responses_are_byte_identical() {
    // Two identical nodes, one per front end.
    let blocking_state = node_state(4, 1);
    let reactor_state = node_state(4, 1);
    let blocking =
        Server::start_blocking("127.0.0.1:0", 2, node_handler(Arc::clone(&blocking_state)))
            .unwrap();
    let reactor = serve(Arc::clone(&reactor_state), "127.0.0.1:0", 2).unwrap();
    assert_eq!(blocking.backend_name(), "blocking");
    // Pin the async path: the default front end must be the reactor on
    // Linux (other platforms fall back to the blocking pool by design).
    if cfg!(target_os = "linux") {
        assert_eq!(reactor.backend_name(), "epoll");
    }

    // A battery covering success paths, every error class the router
    // emits, and keep-alive across all of it — on one connection.
    let battery: Vec<Vec<u8>> = vec![
        raw_request("POST", "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#),
        raw_request("POST", "/v1/insert", r#"{"id":2,"vector":[0.9,0.8,0.7,0.6]}"#),
        raw_request("POST", "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#), // 409
        raw_request("POST", "/v1/query", r#"{"vector":[0.1,0.2,0.3,0.4],"k":2}"#),
        raw_request("POST", "/v1/insert", "{oops"),                                  // 400
        raw_request("POST", "/v1/delete", r#"{"id":99}"#),                           // 404
        raw_request("GET", "/v2/nope", ""),                                          // 404
        raw_request("GET", "/v1/health", ""),
        raw_request("POST", "/v1/link", r#"{"from":1,"to":2}"#),
        raw_request("GET", "/v1/hash", ""),
        raw_request("GET", "/v1/log?from=0", ""),
    ];
    let from_blocking = raw_exchange(&blocking.addr(), &battery);
    let from_reactor = raw_exchange(&reactor.addr(), &battery);
    assert!(
        from_blocking == from_reactor,
        "front ends diverged:\n--- blocking ---\n{}\n--- reactor ---\n{}",
        String::from_utf8_lossy(&from_blocking),
        String::from_utf8_lossy(&from_reactor),
    );

    // Terminal error paths (each closes its connection) — byte-identical
    // too, on fresh sockets.
    for raw in [
        b"NONSENSE\r\n\r\n".to_vec(),
        format!("POST /v1/insert HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1)
            .into_bytes(),
    ] {
        let a = raw_exchange(&blocking.addr(), std::slice::from_ref(&raw));
        let b = raw_exchange(&reactor.addr(), std::slice::from_ref(&raw));
        assert!(a == b, "error path diverged for {raw:?}");
    }

    // Truncated requests (client half-closes mid-request): the reactor's
    // finish_eof must reproduce the blocking parser's wire behavior —
    // serve, 400, or silent close — byte for byte.
    let truncations: [&[u8]; 4] = [
        b"GET /q HTTP/1.1\r\n\r",  // "\r" tail completes the headers: served (404)
        b"GET /q HTTP/1.1\r\nx: y", // truncated header line: 400
        b"GET / SPDY/9\r\n",        // bad version surfaces at the newline: 400
        b"POST /h HTTP/1.1\r\ncontent-length: 5\r\n\r\nab", // EOF mid-body: silence
    ];
    for raw in truncations {
        let a = truncated_exchange(&blocking.addr(), raw);
        let b = truncated_exchange(&reactor.addr(), raw);
        assert!(
            a == b,
            "truncation diverged for {raw:?}:\n--- blocking ---\n{}\n--- reactor ---\n{}",
            String::from_utf8_lossy(&a),
            String::from_utf8_lossy(&b),
        );
    }

    // Identical request streams -> identical kernel state on both nodes.
    assert_eq!(
        blocking_state.with_kernel(Kernel::state_hash),
        reactor_state.with_kernel(Kernel::state_hash)
    );
    blocking.stop();
    reactor.stop();
}

#[test]
fn concurrent_256_keep_alive_clients_match_sequential_run() {
    const CLIENTS: usize = 256;
    const REQUESTS_PER_CLIENT: usize = 50;
    let dim = 8usize;

    // The node under concurrent load, and an identically-seeded mirror
    // representing the sequential run.
    let state = node_state(dim, 4);
    let mirror = node_state(dim, 4);
    for target in [&state, &mirror] {
        for i in 0..300u64 {
            let v: Vec<f32> =
                (0..dim as u64).map(|j| ((i * 7 + j) as f32 * 0.013).sin() * 0.8).collect();
            target.apply(Command::insert(i, v)).unwrap();
        }
    }
    let root_before = state.with_sharded(ShardedKernel::root_hash);
    assert_eq!(root_before, mirror.with_sharded(ShardedKernel::root_hash));

    let server = serve(Arc::clone(&state), "127.0.0.1:0", 4).unwrap();
    let addr = server.addr();

    // 50 distinct query bodies; one sequential client records the
    // reference responses.
    let bodies: Vec<String> = (0..REQUESTS_PER_CLIENT as u64)
        .map(|q| {
            let v: Vec<Json> = (0..dim as u64)
                .map(|j| Json::Float((((q * 31 + j) as f64) * 0.021).cos() * 0.7))
                .collect();
            Json::object(vec![("vector", Json::Array(v)), ("k", Json::Int(10))]).to_string()
        })
        .collect();
    let mut seq_client = client::Connection::connect(&addr).unwrap();
    let reference: Vec<Vec<u8>> = bodies
        .iter()
        .map(|b| {
            let (status, body) = seq_client.request("POST", "/v1/query", b.as_bytes()).unwrap();
            assert_eq!(status, 200);
            body
        })
        .collect();

    // 256 concurrent keep-alive clients re-issue the same 50 requests.
    std::thread::scope(|scope| {
        let bodies = &bodies;
        let reference = &reference;
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let mut conn = client::Connection::connect(&addr).unwrap();
                    for (qi, body) in bodies.iter().enumerate() {
                        let (status, got) =
                            conn.request("POST", "/v1/query", body.as_bytes()).unwrap();
                        assert_eq!(status, 200, "client {c} query {qi}");
                        assert!(
                            got == reference[qi],
                            "client {c} query {qi}: response diverged from sequential run"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    // The kernel is untouched by concurrency: same root as before, and
    // the same root a purely sequential run holds.
    let root_after = state.with_sharded(ShardedKernel::root_hash);
    assert_eq!(root_after, root_before);
    assert_eq!(root_after, mirror.with_sharded(ShardedKernel::root_hash));
    server.stop();
}
