#!/usr/bin/env python3
"""Regenerate `proof_golden.json`, the cross-implementation receipt fixture.

This is an independent mirror — pure hashlib, no Rust — of the canonical
leaf encoding (`rust/src/proof/leaf.rs`), the Merkle tree shape and domain
tags (`rust/src/proof/tree.rs`), and the combined-root fold. The corpus
below mirrors `golden_corpus()` in `rust/tests/proof.rs` command for
command; the test pins every per-slot leaf hash, the shard root, the
combined root, and one membership proof against this file. If the Rust
side and this mirror ever disagree, the encoding drifted.

Usage:
    python3 rust/tests/fixtures/make_proof.py
"""

import hashlib
import json
import os

# Domain tags (tree.rs): leaf 0x00, internal node 0x01, combined root 0x02.
LEAF_DOMAIN = b"\x00"
NODE_DOMAIN = b"\x01"
ROOT_DOMAIN = b"\x02"
# Canonical encoding of a never-used slot.
EMPTY_SLOT = b"\x00"


def sha(data: bytes) -> bytes:
    return hashlib.sha256(data).digest()


def leaf_hash(encoding: bytes) -> bytes:
    return sha(LEAF_DOMAIN + encoding)


def node_hash(left: bytes, right: bytes) -> bytes:
    return sha(NODE_DOMAIN + left + right)


def combined_root(roots: list) -> bytes:
    return sha(ROOT_DOMAIN + len(roots).to_bytes(4, "little") + b"".join(roots))


def u32(n: int) -> bytes:
    return n.to_bytes(4, "little")


def u64(n: int) -> bytes:
    return n.to_bytes(8, "little")


def i32(n: int) -> bytes:
    return n.to_bytes(4, "little", signed=True)


def encode_live(rid: int, raw: list, meta: dict, links: list) -> bytes:
    """0x01 | id | dim | raw i32s | n_meta | sorted kv | n_links | targets."""
    out = b"\x01" + u64(rid) + u32(len(raw)) + b"".join(i32(c) for c in raw)
    out += u32(len(meta))
    for k in sorted(meta):
        v = meta[k]
        out += u32(len(k)) + k.encode() + u32(len(v)) + v.encode()
    out += u32(len(links)) + b"".join(u64(t) for t in links)
    return out


def encode_tombstone(rid: int) -> bytes:
    return b"\x02" + u64(rid)


def main() -> None:
    # Corpus = golden_corpus() in rust/tests/proof.rs: five inserts
    # (dim 3, raw Q16.16 values given directly), two meta pairs on id 1,
    # two outgoing links on id 0, then Delete {id: 3}. Single shard, so
    # slot i simply holds id i.
    slots = []
    for i in range(5):
        raw = [i * 65536, 1000 + i, -i * 7]
        meta = {"kind": "doc", "lang": "en"} if i == 1 else {}
        links = [2, 4] if i == 0 else []
        slots.append(encode_live(i, raw, meta, links))
    slots[3] = encode_tombstone(3)

    capacity = 8  # next_pow2(5 occupied slots)
    leaves = [leaf_hash(s) for s in slots]
    leaves += [leaf_hash(EMPTY_SLOT)] * (capacity - len(leaves))
    levels = [leaves]
    while len(levels[-1]) > 1:
        row = levels[-1]
        levels.append([node_hash(row[i], row[i + 1]) for i in range(0, len(row), 2)])
    shard_root = levels[-1][0]

    # Membership proof for id 1 (slot 1): sibling digests, bottom-up.
    slot = 1
    path, idx = [], slot
    for level in levels[:-1]:
        path.append(level[idx ^ 1])
        idx //= 2

    golden = {
        "version": 1,
        "n_shards": 1,
        "capacity": capacity,
        "leaf_hashes": [h.hex() for h in leaves],
        "shard_root": shard_root.hex(),
        "merkle_root": combined_root([shard_root]).hex(),
        "proof_id1": {
            "id": 1,
            "shard": 0,
            "slot": slot,
            "capacity": capacity,
            "record": slots[1].hex(),
            "path": [h.hex() for h in path],
        },
    }
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "proof_golden.json")
    with open(out, "w") as f:
        json.dump(golden, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
