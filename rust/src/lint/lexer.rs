//! Token-level Rust scanner for the determinism auditor.
//!
//! This is deliberately *not* a parser: the lint's rule set (see
//! [`super::rules`]) only needs the identifier/number/punct stream with
//! line numbers, plus the comment side-channel (`// SAFETY:` and
//! `// lint:` markers). Keeping it token-level means zero dependencies,
//! no syntax-tree drift when rustc grows new syntax, and a scanner small
//! enough to audit by eye — the auditor itself must be auditable.
//!
//! What the scanner understands well enough to never mis-tokenize:
//! line comments, nested block comments, string literals (escaped, raw,
//! byte), char literals vs. lifetimes, numeric literals with suffixes
//! (`1.0f32`, `2f64`, `0x1F`, `1e3`), and raw identifiers (`r#type`).

#![forbid(unsafe_code)]

/// Token classes the rules care about. Strings and chars are kept in the
/// stream (so neighbor lookups stay positional) but carry no text — rule
/// patterns must never match inside literal data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`f32`, `unsafe`, `env`, ...).
    Ident,
    /// Numeric literal, text preserved for float-literal detection.
    Num,
    /// Single punctuation character (`{`, `;`, `#`, ...).
    Punct,
    /// String/char literal (text discarded).
    Lit,
    /// Lifetime (`'a`), kept so `'a` never reads as a char literal.
    Lifetime,
}

/// One source token with its 1-based line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
}

/// One comment (line or block), with the line span it covers and whether
/// code tokens preceded it on its first line (a *trailing* comment).
#[derive(Debug, Clone)]
pub struct Comment {
    pub first_line: u32,
    pub last_line: u32,
    pub text: String,
    pub trailing: bool,
}

/// Scanner output: the code-token stream plus the comment side-channel.
#[derive(Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
}

impl Scan {
    /// Lines that carry at least one code token.
    pub fn token_lines(&self) -> std::collections::BTreeSet<u32> {
        self.tokens.iter().map(|t| t.line).collect()
    }
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_char(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Does this numeric-literal text denote a float? Covers `1.5`, `1e3`,
/// `1.0e-3`, and suffixed forms (`2f64`); hex/octal/binary never float.
pub fn is_float_literal(text: &str) -> bool {
    if text.starts_with("0x") || text.starts_with("0o") || text.starts_with("0b") {
        return false;
    }
    if text.ends_with("f32") || text.ends_with("f64") {
        return true;
    }
    if text.contains('.') {
        return true;
    }
    // an exponent needs a digit after the `e` (`1e3`, `1e-3`); a bare
    // `e` inside an int suffix (`7usize`) is not one
    let b = text.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if matches!(c, b'e' | b'E') {
            let mut j = i + 1;
            if j < b.len() && matches!(b[j], b'+' | b'-') {
                j += 1;
            }
            while j < b.len() && b[j] == b'_' {
                j += 1;
            }
            if j < b.len() && b[j].is_ascii_digit() {
                return true;
            }
        }
    }
    false
}

/// Tokenize `src`. Never fails: unrecognized bytes are emitted as punct
/// so a weird file degrades to noisy tokens, not a lost audit.
pub fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let mut out = Scan::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut last_tok_line = 0u32;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            out.comments.push(Comment {
                first_line: line,
                last_line: line,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
                trailing: last_tok_line == line,
            });
            continue;
        }
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let first = line;
            let trailing = last_tok_line == line;
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment {
                first_line: first,
                last_line: line,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
                trailing,
            });
            continue;
        }
        // strings
        if c == b'"' {
            i = skip_escaped_string(b, i + 1, &mut line);
            out.tokens.push(Tok { line, kind: TokKind::Lit, text: String::new() });
            last_tok_line = line;
            continue;
        }
        // char literal or lifetime
        if c == b'\'' {
            let (next, kind) = scan_quote(b, i, &mut line);
            i = next;
            out.tokens.push(Tok { line, kind, text: String::new() });
            last_tok_line = line;
            continue;
        }
        // identifiers (and raw-string / raw-ident prefixes)
        if is_ident_start(c) {
            let start = i;
            while i < b.len() && is_ident_char(b[i]) {
                i += 1;
            }
            let ident = &src[start..i];
            if matches!(ident, "r" | "b" | "br") {
                if let Some(next) = try_raw_or_byte_string(b, i, ident, &mut line) {
                    i = next;
                    out.tokens.push(Tok { line, kind: TokKind::Lit, text: String::new() });
                    last_tok_line = line;
                    continue;
                }
            }
            // raw identifier r#name: emit the name itself
            if ident == "r"
                && i + 1 < b.len()
                && b[i] == b'#'
                && is_ident_start(b[i + 1])
            {
                let rstart = i + 1;
                i += 1;
                while i < b.len() && is_ident_char(b[i]) {
                    i += 1;
                }
                out.tokens.push(Tok {
                    line,
                    kind: TokKind::Ident,
                    text: src[rstart..i].to_string(),
                });
                last_tok_line = line;
                continue;
            }
            out.tokens.push(Tok { line, kind: TokKind::Ident, text: ident.to_string() });
            last_tok_line = line;
            continue;
        }
        // numbers
        if c.is_ascii_digit() {
            let start = i;
            if c == b'0' && i + 1 < b.len() && matches!(b[i + 1], b'x' | b'o' | b'b') {
                i += 2;
                while i < b.len() && (is_ident_char(b[i])) {
                    i += 1;
                }
            } else {
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
                    i += 1;
                }
                // fractional part: `1.5` yes, `1..3` / `x.method()` no
                if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
                        i += 1;
                    }
                }
                // exponent
                if i < b.len() && matches!(b[i], b'e' | b'E') {
                    let sign = i + 1 < b.len() && matches!(b[i + 1], b'+' | b'-');
                    let digit_at = i + if sign { 2 } else { 1 };
                    if digit_at < b.len() && b[digit_at].is_ascii_digit() {
                        i = digit_at + 1;
                        while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
                            i += 1;
                        }
                    }
                }
                // type suffix (`u64`, `f32`, `usize`, ...)
                while i < b.len() && is_ident_char(b[i]) {
                    i += 1;
                }
            }
            out.tokens.push(Tok { line, kind: TokKind::Num, text: src[start..i].to_string() });
            last_tok_line = line;
            continue;
        }
        // everything else: single punct char (multi-byte UTF-8 bytes land
        // here too; they only occur inside comments/strings in practice)
        out.tokens.push(Tok {
            line,
            kind: TokKind::Punct,
            text: (c as char).to_string(),
        });
        last_tok_line = line;
        i += 1;
    }
    out
}

/// Skip past a `"`-delimited string with backslash escapes. `i` points
/// just after the opening quote; returns the index after the closer.
fn skip_escaped_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// `'` at `b[i]`: decide char literal vs. lifetime and skip it.
fn scan_quote(b: &[u8], i: usize, line: &mut u32) -> (usize, TokKind) {
    let mut j = i + 1;
    if j >= b.len() {
        return (j, TokKind::Punct);
    }
    if b[j] == b'\\' {
        // escaped char: skip the backslash and the escaped character,
        // then scan to the closing quote (handles '\u{..}', '\x41', '\'')
        j += 2;
        while j < b.len() && b[j] != b'\'' {
            if b[j] == b'\n' {
                *line += 1;
            }
            j += 1;
        }
        return (j + 1, TokKind::Lit);
    }
    if is_ident_start(b[j]) {
        // 'a' is a char literal; 'a (no closing quote) is a lifetime
        let mut k = j;
        while k < b.len() && is_ident_char(b[k]) {
            k += 1;
        }
        if k < b.len() && b[k] == b'\'' {
            return (k + 1, TokKind::Lit);
        }
        return (k, TokKind::Lifetime);
    }
    // non-identifier char ('+', multi-byte UTF-8, ...): scan to closer
    while j < b.len() && b[j] != b'\'' {
        if b[j] == b'\n' {
            *line += 1;
        }
        j += 1;
    }
    (j + 1, TokKind::Lit)
}

/// After the ident `r` / `b` / `br` at `b[i]`: if a raw/byte string
/// follows, skip it and return the index after its closer.
fn try_raw_or_byte_string(
    b: &[u8],
    i: usize,
    prefix: &str,
    line: &mut u32,
) -> Option<usize> {
    if i >= b.len() {
        return None;
    }
    if prefix == "b" && b[i] == b'"' {
        return Some(skip_escaped_string(b, i + 1, line));
    }
    // raw forms: r"..."  r#"..."#  br#"..."#
    let mut hashes = 0usize;
    let mut j = i;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' || (prefix == "b" && hashes == 0) {
        return None;
    }
    if prefix == "b" {
        return None; // b#"..." is not a string form
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            let end = j + 1;
            let mut h = 0usize;
            while h < hashes && end + h < b.len() && b[end + h] == b'#' {
                h += 1;
            }
            if h == hashes {
                return Some(end + hashes);
            }
        }
        j += 1;
    }
    Some(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        scan(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_never_leak_tokens() {
        let src = r##"
            // f32 in a comment
            /* f64 in a /* nested */ block */
            let s = "f32 inside a string";
            let r = r#"f64 raw "quoted" string"#;
            let b = b"bytes f32";
            let c = '\'';
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|t| t == "f32" || t == "f64"), "{ids:?}");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let s = scan(src);
        assert!(s.tokens.iter().any(|t| t.kind == TokKind::Lifetime));
        // the str idents after lifetimes still tokenize
        assert_eq!(s.tokens.iter().filter(|t| t.text == "str").count(), 3);
    }

    #[test]
    fn float_literals_are_classified() {
        for (text, want) in [
            ("1.5", true),
            ("1.0e-3", true),
            ("2f64", true),
            ("1e3", true),
            ("3f32", true),
            ("42", false),
            ("1u64", false),
            ("7usize", false),
            ("0x1E", false),
            ("0b101", false),
            ("1_000", false),
        ] {
            assert_eq!(is_float_literal(text), want, "{text}");
        }
    }

    #[test]
    fn range_and_tuple_dots_are_not_floats() {
        let s = scan("let a = 0..10; let b = t.0; let c = 1.5;");
        let nums: Vec<&str> = s
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "10", "0", "1.5"]);
    }

    #[test]
    fn comment_spans_and_trailing_flags() {
        let src = "let x = 1; // trailing\n/* block\nspans */\nlet y = 2;\n";
        let s = scan(src);
        assert_eq!(s.comments.len(), 2);
        assert!(s.comments[0].trailing);
        assert_eq!((s.comments[1].first_line, s.comments[1].last_line), (2, 3));
        assert!(!s.comments[1].trailing);
    }

    #[test]
    fn raw_identifiers_emit_the_inner_name() {
        let ids = idents("let r#type = 1;");
        assert!(ids.contains(&"type".to_string()));
    }
}
