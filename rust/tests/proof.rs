//! Integration: the verifiable-receipt subsystem (paper §8, PR-10).
//!
//! Three independent implementations of the same hash chain must agree:
//!
//! 1. the **incremental** tree the kernel maintains on every applied
//!    command (O(log n) path recompute, `rust/src/proof/`),
//! 2. a **naive full rebuild** done here from the slot encodings, and
//! 3. a **Python mirror** (`tests/fixtures/make_proof.py`, pure hashlib)
//!    whose output is pinned in `tests/fixtures/proof_golden.json`.
//!
//! Plus the offline-verification contract: every live id and tombstone
//! proves membership against the current roots, and any single-bit tamper
//! in the record, the path, or the receipt is rejected.

use valori::hash::{hex_lower, hex_to_digest};
use valori::proof::tree::EMPTY_SLOT_ENCODING;
use valori::proof::{
    combined_root, leaf, leaf_hash, node_hash, verify_membership, verify_receipt, LeafBody,
    MembershipProof, Receipt,
};
use valori::state::{CanonCommand, Command, Kernel, KernelConfig, ShardedKernel};

const GOLDEN: &str = include_str!("fixtures/proof_golden.json");

/// Full from-scratch rebuild of one shard's Merkle root, sharing only the
/// primitive hash functions with the incremental implementation.
fn naive_shard_root(k: &Kernel) -> [u8; 32] {
    let mut layer: Vec<[u8; 32]> = (0..k.merkle_capacity())
        .map(|slot| {
            let enc = k
                .merkle_leaf_encoding(slot as u32)
                .unwrap_or_else(|| EMPTY_SLOT_ENCODING.to_vec());
            leaf_hash(&enc)
        })
        .collect();
    while layer.len() > 1 {
        layer = layer.chunks_exact(2).map(|p| node_hash(&p[0], &p[1])).collect();
    }
    layer[0]
}

/// A receipt carrying only the Merkle side (snapshot/wal hashes are not
/// under test here; `verify_receipt` checks the root fold alone).
fn merkle_receipt(sk: &ShardedKernel) -> Receipt {
    Receipt {
        state_version: sk.shard(0).state_version(),
        seq: sk.seq(),
        snapshot_hash: [0; 32],
        wal_hash: 0,
        merkle_root: sk.merkle_root(),
        shard_roots: sk.merkle_shard_roots(),
    }
}

#[test]
fn incremental_tree_matches_naive_rebuild_across_shard_counts() {
    for n_shards in [1u32, 2, 4, 8] {
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(6), n_shards);
        for i in 0..40u64 {
            let v: Vec<f32> = (0..6).map(|j| ((i * 6 + j) as f32 * 0.017).sin() * 0.7).collect();
            sk.apply(Command::insert(i, v)).unwrap();
        }
        sk.apply(Command::Link { from: 2, to: 5 }).unwrap();
        sk.apply(Command::Link { from: 2, to: 9 }).unwrap();
        sk.apply(Command::SetMeta { id: 5, key: "kind".into(), value: "doc".into() })
            .unwrap();
        sk.apply(Command::Delete { id: 17 }).unwrap();

        for s in 0..n_shards {
            assert_eq!(
                sk.shard(s).merkle_root(),
                naive_shard_root(sk.shard(s)),
                "n_shards={n_shards} shard={s}"
            );
        }
        assert_eq!(sk.merkle_root(), combined_root(&sk.merkle_shard_roots()));

        let receipt = merkle_receipt(&sk);
        assert_eq!(verify_receipt(&receipt), Ok(()));
        // every id ever inserted proves membership — including the
        // deleted one, which proves as a tombstone
        for id in 0..40u64 {
            let proof = sk.merkle_proof(id).expect("proof for inserted id");
            assert_eq!(
                verify_membership(&proof, &receipt),
                Ok(()),
                "n_shards={n_shards} id={id}"
            );
            let rec = leaf::decode(&proof.record).unwrap();
            assert_eq!(rec.id, id);
            let is_tomb = matches!(rec.body, LeafBody::Tombstone);
            assert_eq!(is_tomb, id == 17, "id={id}");
        }
        assert_eq!(sk.merkle_proof(40), None, "never-inserted id has no proof");

        // single-bit tampers are rejected offline
        let good = sk.merkle_proof(3).unwrap();
        let mut p = good.clone();
        p.record[9] ^= 0x80;
        assert!(verify_membership(&p, &receipt).is_err(), "tampered record accepted");
        if !good.path.is_empty() {
            let mut p = good.clone();
            p.path[0][0] ^= 1;
            assert!(verify_membership(&p, &receipt).is_err(), "tampered path accepted");
        }
        let mut r = receipt.clone();
        r.merkle_root[31] ^= 1;
        assert!(verify_receipt(&r).is_err(), "tampered receipt accepted");
    }
}

#[test]
fn shard_count_changes_the_combined_root_but_not_determinism() {
    // Same logical content under different shardings gives different
    // roots (shard layout is part of the receipt), but rebuilding with
    // the same shard count from the same canonical log is bit-identical.
    let build = |n_shards: u32| {
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(4), n_shards);
        for i in 0..12u64 {
            sk.apply_canon(&CanonCommand::Insert {
                id: i,
                raw: vec![i as i32 * 19 - 5, 7, -(i as i32), 65536],
            })
            .unwrap();
        }
        sk.apply_canon(&CanonCommand::Delete { id: 4 }).unwrap();
        sk
    };
    assert_eq!(build(2).merkle_root(), build(2).merkle_root());
    assert_eq!(build(2).merkle_shard_roots(), build(2).merkle_shard_roots());
    assert_ne!(build(2).merkle_root(), build(4).merkle_root());
}

/// The command corpus mirrored by `fixtures/make_proof.py`. Raw Q16.16
/// components are given directly (no float quantization in the chain), so
/// the Python side reproduces the exact bytes.
fn golden_corpus() -> Vec<CanonCommand> {
    let mut cmds: Vec<CanonCommand> = (0..5u64)
        .map(|i| CanonCommand::Insert {
            id: i,
            raw: vec![i as i32 * 65536, 1000 + i as i32, -(i as i32) * 7],
        })
        .collect();
    cmds.push(CanonCommand::SetMeta { id: 1, key: "kind".into(), value: "doc".into() });
    cmds.push(CanonCommand::SetMeta { id: 1, key: "lang".into(), value: "en".into() });
    cmds.push(CanonCommand::Link { from: 0, to: 2 });
    cmds.push(CanonCommand::Link { from: 0, to: 4 });
    cmds.push(CanonCommand::Delete { id: 3 });
    cmds
}

#[test]
fn golden_receipt_fixture_pins_the_hash_chain() {
    let golden = valori::json::parse(GOLDEN).expect("fixture parses");
    let mut k = Kernel::new(KernelConfig::default_q16(3));
    for c in golden_corpus() {
        k.apply_canon(&c).unwrap();
    }

    assert_eq!(k.merkle_capacity() as u64, golden.get("capacity").as_u64().unwrap());
    let want = golden.get("leaf_hashes").as_array().unwrap();
    assert_eq!(want.len(), k.merkle_capacity());
    for (slot, w) in want.iter().enumerate() {
        let enc = k
            .merkle_leaf_encoding(slot as u32)
            .unwrap_or_else(|| EMPTY_SLOT_ENCODING.to_vec());
        assert_eq!(hex_lower(&leaf_hash(&enc)), w.as_str().unwrap(), "slot {slot}");
    }
    assert_eq!(hex_lower(&k.merkle_root()), golden.get("shard_root").as_str().unwrap());
    let shard_root = hex_to_digest(golden.get("shard_root").as_str().unwrap()).unwrap();
    assert_eq!(
        hex_lower(&combined_root(&[shard_root])),
        golden.get("merkle_root").as_str().unwrap()
    );

    // the proof the kernel serves for id 1 is byte-identical to the
    // Python mirror's, and verifies offline against the golden roots
    let live = k.merkle_proof(1).unwrap();
    let pinned = MembershipProof::from_json(golden.get("proof_id1")).expect("fixture proof");
    assert_eq!(live, pinned);
    let receipt = Receipt {
        state_version: k.state_version(),
        seq: k.seq(),
        snapshot_hash: [0; 32],
        wal_hash: 0,
        merkle_root: hex_to_digest(golden.get("merkle_root").as_str().unwrap()).unwrap(),
        shard_roots: vec![shard_root],
    };
    assert_eq!(verify_membership(&pinned, &receipt), Ok(()));
    // slot 3 was deleted: the fixture's leaf hash at slot 3 covers a
    // tombstone, and the kernel agrees
    let rec = leaf::decode(&k.merkle_leaf_encoding(3).unwrap()).unwrap();
    assert_eq!(rec, leaf::LeafRecord { id: 3, body: LeafBody::Tombstone });
}
