//! Bench/driver for **Table 1** — bit-level divergence of identical
//! embeddings (paper §4.2). Prints the paper's table (hex of the first 5
//! dimensions under two evaluation environments) and times the embedding
//! path.
//!
//! Run: `cargo bench --bench table1_divergence`
//! Quick: `VALORI_BENCH_QUICK=1 cargo bench --bench table1_divergence`

use valori::bench::{bench, BenchConfig, Report};
use valori::corpus::CorpusGen;
use valori::distance::float;
use valori::experiments::divergence;
use valori::hash::XorShift64;

fn main() {
    let cfg = if std::env::var("VALORI_BENCH_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };

    // The paper's table, through the AOT stack when available.
    let result = divergence::run(5);
    divergence::print_table(&result);

    // Divergence frequency across the paper's full sentence set (fallback
    // mechanism): how often do legal evaluation orders change the bits?
    let mut rng = XorShift64::new(123);
    let dims = [64usize, 128, 384, 768];
    println!("\nreduction-order divergence frequency (100 random vector pairs each):");
    for dim in dims {
        let mut diverged = 0;
        for _ in 0..100 {
            let a: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
            if float::divergent_variants(&a, &b) > 0 {
                diverged += 1;
            }
        }
        println!("  dim {dim:>4}: {diverged}/100 pairs give different bits across eval orders");
    }

    // Timing: the float dot variants (the operations whose order matters).
    let mut report = Report::new("dot-product evaluation variants (dim 384)");
    let a: Vec<f32> = (0..384).map(|i| ((i * 37) as f32 * 0.01).sin()).collect();
    let b: Vec<f32> = (0..384).map(|i| ((i * 11) as f32 * 0.02).cos()).collect();
    report.add("seq", bench(&cfg, || float::dot_f32_seq(&a, &b)));
    report.add("rev", bench(&cfg, || float::dot_f32_rev(&a, &b)));
    report.add("pairwise", bench(&cfg, || float::dot_f32_pairwise(&a, &b)));
    report.add("lanes8 (simd model)", bench(&cfg, || float::dot_f32_lanes8(&a, &b)));
    report.add("fma", bench(&cfg, || float::dot_f32_fma(&a, &b)));
    report.note("all mathematically equal; bits differ — the paper's §2.1 root cause");
    report.print();

    // If artifacts exist, time the full embed path too.
    if valori::runtime::artifacts_available() {
        let engine = valori::runtime::Engine::cpu().expect("pjrt");
        let embedder = valori::runtime::Embedder::load(
            &engine,
            valori::runtime::artifacts_dir(),
            valori::runtime::embedder::Env::A,
        )
        .expect("embedder");
        let sentences = CorpusGen::paper_sentences();
        let mut report = Report::new("AOT embedder (batch of 5 paper sentences)");
        report.add(
            "embed_texts (PJRT)",
            bench(&BenchConfig::quick(), || embedder.embed_texts(&sentences).unwrap()),
        );
        report.print();
    }
}
