//! Bench/driver for **Table 2** — precision layers as configurable
//! contracts (paper §6). Prints the quantitative contract table and times
//! quantization + arithmetic throughput per format.
//!
//! Run: `cargo bench --bench table2_precision`

use valori::bench::{bench, BenchConfig, Report};
use valori::experiments::precision;
use valori::fixed::{ops, FixedFormat, Q16_16, Q32_32, Q8_24};
use valori::hash::XorShift64;

fn main() {
    let cfg = if std::env::var("VALORI_BENCH_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };

    let rows = precision::run();
    precision::print_table(&rows);

    // Quantization throughput (128-dim vector through the boundary).
    let mut rng = XorShift64::new(5);
    let v: Vec<f64> = (0..128).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
    let mut report = Report::new("boundary quantization, 128-dim vector");
    report.add("Q8.24", bench(&cfg, || v.iter().map(|&x| Q8_24::quantize(x)).collect::<Vec<_>>()));
    report
        .add("Q16.16", bench(&cfg, || v.iter().map(|&x| Q16_16::quantize(x)).collect::<Vec<_>>()));
    report
        .add("Q32.32", bench(&cfg, || v.iter().map(|&x| Q32_32::quantize(x)).collect::<Vec<_>>()));
    report.print();

    // Dot-product throughput per contract (the §6 performance/precision
    // trade-off, quantified).
    let a16: Vec<i32> = (0..128).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();
    let b16: Vec<i32> = (0..128).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();
    let a32: Vec<i64> = a16.iter().map(|&x| (x as i64) << 16).collect();
    let b32: Vec<i64> = b16.iter().map(|&x| (x as i64) << 16).collect();
    let mut report = Report::new("dot product per contract, dim 128");
    report.add("Q16.16 (i64 acc)", bench(&cfg, || Q16_16::dot_wide(&a16, &b16)));
    report.add("Q8.24  (i64 acc)", bench(&cfg, || Q8_24::dot_wide(&a16, &b16)));
    report.add("Q32.32 (i128 acc)", bench(&cfg, || Q32_32::dot_wide(&a32, &b32)));
    report.note("determinism holds for every contract; cost scales with accumulator width");
    report.print();

    // Fixed-point normalization (the in-kernel op the normalize policy
    // runs per insert).
    let mut v16 = a16.clone();
    let mut report = Report::new("fixed-point L2 normalize, dim 128");
    report.add(
        "normalize_q16",
        bench(&cfg, || {
            let mut c = v16.clone();
            ops::normalize_q16(&mut c);
            c
        }),
    );
    v16[0] ^= 1;
    report.print();
}
