//! The closed determinism rule set (R1–R6) over the token stream.
//!
//! | rule | zone     | what it rejects                                       |
//! |------|----------|-------------------------------------------------------|
//! | R1   | state    | `f32`/`f64` type tokens and float literals, unless the
//! |      |          | site is inside a `// lint: float-boundary — why` item |
//! | R2   | state+boundary | `HashMap` / `HashSet` / `RandomState` (iteration
//! |      |          | order is hash-seed randomized)                        |
//! | R3   | state    | `Instant` / `SystemTime` (wall-clock reads)           |
//! | R4   | state    | `rand::` / `getrandom` / OS rngs / `env::var*`        |
//! | R5   | all      | `unsafe` outside the allowlisted files; inside them,  |
//! |      |          | every `unsafe` needs a `// SAFETY:` comment (and      |
//! |      |          | `SAFETY: TODO` stubs still fail)                      |
//! | R6   | state    | platform-width or native-endian encode/decode:        |
//! |      |          | `usize`/`isize` `to/from_*_bytes`, `to_ne_bytes`,     |
//! |      |          | `put_usize`/`get_usize`                               |
//!
//! `#[cfg(test)]` items are exempt from R1–R4/R6 (tests may read clocks
//! and print floats); R5 applies everywhere — unsafe in a test block of
//! a non-allowlisted file is still a finding.
//!
//! Suppression is explicit and auditable: a standalone
//! `// lint: float-boundary — <one-line justification>` comment covers
//! the next item (to the end of its brace block, or its terminating
//! `;`); a trailing one covers only its own line. A marker without a
//! justification, or an unknown `// lint:` marker, is itself a finding.

#![forbid(unsafe_code)]

use super::lexer::{is_float_literal, Comment, Scan, Tok, TokKind};
use super::{Finding, Rule, Zone};
use std::collections::BTreeSet;

/// Files allowed to contain `unsafe` (R5), relative to the audit root.
pub const UNSAFE_ALLOWLIST: &[&str] = &["state/sharded.rs", "http/reactor.rs"];

/// The annotation marker the float-boundary suppression looks for.
pub const FLOAT_BOUNDARY_MARKER: &str = "float-boundary";

/// An inclusive line range.
#[derive(Debug, Clone, Copy)]
struct Span {
    first: u32,
    last: u32,
}

impl Span {
    fn contains(&self, line: u32) -> bool {
        (self.first..=self.last).contains(&line)
    }
}

/// A parsed `// lint:` comment.
#[derive(Debug)]
struct Annotation {
    line: u32,
    trailing: bool,
    marker: String,
    has_reason: bool,
}

fn parse_annotation(c: &Comment) -> Option<Annotation> {
    let pos = c.text.find("lint:")?;
    // only honor the marker in a comment, right after the comment
    // leader — `"lint:"` inside prose does not count
    let lead: String = c.text[..pos]
        .chars()
        .filter(|ch| !ch.is_whitespace())
        .collect();
    if !matches!(lead.as_str(), "//" | "///" | "//!" | "/*" | "/**" | "/*!") {
        return None;
    }
    let rest = c.text[pos + "lint:".len()..].trim();
    let (marker, tail) = match rest.split_once(char::is_whitespace) {
        Some((m, t)) => (m, t),
        None => (rest, ""),
    };
    let marker = marker.trim_end_matches(|ch| ch == ':' || ch == ',');
    let reason = tail
        .trim_start_matches(|ch: char| {
            ch.is_whitespace() || matches!(ch, '-' | '—' | '–' | ':' | '.')
        })
        .trim_end_matches("*/")
        .trim();
    Some(Annotation {
        line: c.first_line,
        trailing: c.trailing,
        marker: marker.to_string(),
        has_reason: !reason.is_empty(),
    })
}

/// Is the `cfg(...)` predicate (tokens between the outer parens)
/// test-gated? `test` counts unless it sits under a `not(...)`.
fn cfg_is_test_gated(toks: &[&Tok]) -> bool {
    let mut stack: Vec<String> = Vec::new();
    let mut prev_ident = String::new();
    for t in toks {
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "(") => {
                stack.push(std::mem::take(&mut prev_ident));
            }
            (TokKind::Punct, ")") => {
                stack.pop();
            }
            (TokKind::Ident, name) => {
                if name == "test" && !stack.iter().any(|s| s == "not") {
                    return true;
                }
                prev_ident = name.to_string();
            }
            _ => prev_ident.clear(),
        }
    }
    false
}

/// From token index `start`, find the line where the item ends: the
/// matching `}` of the first brace block, or a `;` before any brace.
fn item_end_line(tokens: &[Tok], start: usize) -> u32 {
    let mut depth = 0i32;
    for t in &tokens[start..] {
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "{") => depth += 1,
            (TokKind::Punct, "}") => {
                depth -= 1;
                if depth <= 0 {
                    return t.line;
                }
            }
            (TokKind::Punct, ";") if depth == 0 => return t.line,
            _ => {}
        }
    }
    tokens.last().map(|t| t.line).unwrap_or(0)
}

/// Line ranges of `#[cfg(test)]`-gated items.
fn test_spans(tokens: &[Tok]) -> Vec<Span> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        let is_attr_start = tokens[i].text == "#"
            && tokens[i].kind == TokKind::Punct
            && tokens.get(i + 1).is_some_and(|t| t.text == "[");
        if !is_attr_start {
            i += 1;
            continue;
        }
        let attr_line = tokens[i].line;
        // collect the bracket group
        let mut j = i + 1;
        let mut bdepth = 0i32;
        let mut group: Vec<&Tok> = Vec::new();
        while j < tokens.len() {
            match tokens[j].text.as_str() {
                "[" => bdepth += 1,
                "]" => {
                    bdepth -= 1;
                    if bdepth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            group.push(&tokens[j]);
            j += 1;
        }
        // gated iff the group is `cfg( <test-gated predicate> )`
        let gated = group.len() > 2
            && group[1].kind == TokKind::Ident
            && group[1].text == "cfg"
            && cfg_is_test_gated(&group[2..]);
        if gated {
            let end = item_end_line(tokens, j + 1);
            spans.push(Span { first: attr_line, last: end.max(attr_line) });
        }
        i = j + 1;
    }
    spans
}

/// Context shared by the per-token rule checks.
pub struct RuleContext<'a> {
    file: &'a str,
    zone: Zone,
    allowlisted_unsafe: bool,
    scan: &'a Scan,
    token_lines: BTreeSet<u32>,
    test_spans: Vec<Span>,
    float_ok_spans: Vec<Span>,
    safety_lines: BTreeSet<u32>,
    safety_todo_lines: BTreeSet<u32>,
}

impl<'a> RuleContext<'a> {
    pub fn new(file: &'a str, zone: Zone, scan: &'a Scan) -> (Self, Vec<Finding>) {
        let mut findings = Vec::new();
        let token_lines = scan.token_lines();
        let mut float_ok_spans = Vec::new();
        for c in &scan.comments {
            let Some(ann) = parse_annotation(c) else { continue };
            if ann.marker != FLOAT_BOUNDARY_MARKER {
                findings.push(Finding {
                    rule: Rule::R1,
                    file: file.to_string(),
                    line: ann.line,
                    zone,
                    key: "bad-annotation".to_string(),
                    message: format!("unknown lint marker `lint: {}`", ann.marker),
                });
                continue;
            }
            if !ann.has_reason {
                findings.push(Finding {
                    rule: Rule::R1,
                    file: file.to_string(),
                    line: ann.line,
                    zone,
                    key: "bad-annotation".to_string(),
                    message: "float-boundary annotation without a justification".to_string(),
                });
                continue;
            }
            if ann.trailing {
                float_ok_spans.push(Span { first: ann.line, last: ann.line });
            } else {
                // standalone: cover the next item
                let start = scan.tokens.iter().position(|t| t.line > ann.line);
                if let Some(s) = start {
                    let first = scan.tokens[s].line;
                    let last = item_end_line(&scan.tokens, s);
                    float_ok_spans.push(Span { first, last: last.max(first) });
                }
            }
        }
        let mut safety_lines = BTreeSet::new();
        let mut safety_todo_lines = BTreeSet::new();
        for c in &scan.comments {
            if let Some(pos) = c.text.find("SAFETY:") {
                for l in c.first_line..=c.last_line {
                    safety_lines.insert(l);
                }
                let after = c.text[pos + "SAFETY:".len()..].trim_start();
                if after.starts_with("TODO") {
                    for l in c.first_line..=c.last_line {
                        safety_todo_lines.insert(l);
                    }
                }
            }
        }
        let ctx = RuleContext {
            file,
            zone,
            allowlisted_unsafe: UNSAFE_ALLOWLIST.contains(&file),
            scan,
            token_lines,
            test_spans: test_spans(&scan.tokens),
            float_ok_spans,
            safety_lines,
            safety_todo_lines,
        };
        (ctx, findings)
    }

    fn in_test(&self, line: u32) -> bool {
        self.test_spans.iter().any(|s| s.contains(line))
    }

    fn float_ok(&self, line: u32) -> bool {
        self.float_ok_spans.iter().any(|s| s.contains(line))
    }

    /// Walk upward over comment/blank lines looking for `// SAFETY:`;
    /// a trailing SAFETY comment on the `unsafe` line itself also counts.
    fn safety_near(&self, line: u32) -> Option<bool> {
        // Some(todo?) if a SAFETY comment covers this unsafe
        if self.safety_lines.contains(&line) {
            return Some(self.safety_todo_lines.contains(&line));
        }
        let mut j = line.saturating_sub(1);
        while j >= 1 && !self.token_lines.contains(&j) {
            if self.safety_lines.contains(&j) {
                return Some(self.safety_todo_lines.contains(&j));
            }
            if j == 1 {
                break;
            }
            j -= 1;
        }
        None
    }

    fn finding(&self, rule: Rule, line: u32, key: &str, message: String) -> Finding {
        Finding {
            rule,
            file: self.file.to_string(),
            line,
            zone: self.zone,
            key: key.to_string(),
            message,
        }
    }

    /// Run R1–R6 over the token stream, appending to `findings`.
    pub fn check(&self, findings: &mut Vec<Finding>) {
        let toks = &self.scan.tokens;
        for (i, t) in toks.iter().enumerate() {
            let in_test = self.in_test(t.line);
            // R5 is file-scoped and applies to test code too.
            if t.kind == TokKind::Ident && t.text == "unsafe" {
                if !self.allowlisted_unsafe {
                    findings.push(self.finding(
                        Rule::R5,
                        t.line,
                        "unsafe-outside-allowlist",
                        format!("`unsafe` in non-allowlisted file {}", self.file),
                    ));
                } else {
                    match self.safety_near(t.line) {
                        None => findings.push(self.finding(
                            Rule::R5,
                            t.line,
                            "missing-safety-comment",
                            "`unsafe` without a `// SAFETY:` comment".to_string(),
                        )),
                        Some(true) => findings.push(self.finding(
                            Rule::R5,
                            t.line,
                            "todo-safety-comment",
                            "`// SAFETY: TODO` stub must be filled in".to_string(),
                        )),
                        Some(false) => {}
                    }
                }
            }
            if in_test {
                continue;
            }
            match t.kind {
                TokKind::Ident => self.check_ident(i, t, findings),
                TokKind::Num => {
                    if self.zone == Zone::State
                        && is_float_literal(&t.text)
                        && !self.float_ok(t.line)
                    {
                        findings.push(self.finding(
                            Rule::R1,
                            t.line,
                            "float-literal",
                            format!("float literal `{}` in state zone", t.text),
                        ));
                    }
                }
                _ => {}
            }
        }
    }

    fn check_ident(&self, i: usize, t: &Tok, findings: &mut Vec<Finding>) {
        let toks = &self.scan.tokens;
        let text = t.text.as_str();
        // R1: float types
        if self.zone == Zone::State && matches!(text, "f32" | "f64") && !self.float_ok(t.line) {
            findings.push(self.finding(
                Rule::R1,
                t.line,
                text,
                format!("`{text}` in state zone without a float-boundary annotation"),
            ));
        }
        // R2: hash-randomized collections
        if self.zone != Zone::Exempt && matches!(text, "HashMap" | "HashSet" | "RandomState") {
            findings.push(self.finding(
                Rule::R2,
                t.line,
                text,
                format!("`{text}` iteration order is hash-seed randomized"),
            ));
        }
        // R3: wall-clock reads
        if self.zone == Zone::State && matches!(text, "Instant" | "SystemTime") {
            findings.push(self.finding(
                Rule::R3,
                t.line,
                text,
                format!("`{text}` wall-clock read in state zone"),
            ));
        }
        // R4: randomness and environment-derived values
        if self.zone == Zone::State {
            if matches!(text, "getrandom" | "thread_rng" | "ThreadRng" | "OsRng" | "from_entropy") {
                findings.push(self.finding(
                    Rule::R4,
                    t.line,
                    text,
                    format!("`{text}` nondeterministic randomness in state zone"),
                ));
            }
            if text == "rand" && self.path_sep_follows(i) {
                findings.push(self.finding(
                    Rule::R4,
                    t.line,
                    "rand",
                    "`rand::` in state zone".to_string(),
                ));
            }
            if text == "env" && self.path_sep_follows(i) {
                if let Some(next) = self.ident_after_path_sep(i) {
                    if matches!(next, "var" | "var_os" | "vars" | "vars_os" | "args") {
                        findings.push(self.finding(
                            Rule::R4,
                            t.line,
                            "env",
                            format!("`env::{next}` environment read in state zone"),
                        ));
                    }
                }
            }
        }
        // R6: platform-width / native-endian encode paths
        if self.zone == Zone::State {
            if matches!(text, "to_ne_bytes" | "from_ne_bytes") {
                findings.push(self.finding(
                    Rule::R6,
                    t.line,
                    text,
                    format!("`{text}` native endianness in state zone"),
                ));
            }
            if matches!(text, "to_le_bytes" | "to_be_bytes" | "from_le_bytes" | "from_be_bytes") {
                let lookback = toks[i.saturating_sub(4)..i]
                    .iter()
                    .any(|p| p.kind == TokKind::Ident && (p.text == "usize" || p.text == "isize"));
                if lookback {
                    findings.push(self.finding(
                        Rule::R6,
                        t.line,
                        text,
                        format!("`usize::{text}` platform-width encode in state zone"),
                    ));
                }
            }
            if matches!(text, "put_usize" | "get_usize") {
                findings.push(self.finding(
                    Rule::R6,
                    t.line,
                    text,
                    format!("`{text}` platform-width codec call"),
                ));
            }
        }
    }

    fn path_sep_follows(&self, i: usize) -> bool {
        let toks = &self.scan.tokens;
        toks.get(i + 1).is_some_and(|a| a.text == ":")
            && toks.get(i + 2).is_some_and(|b| b.text == ":")
    }

    fn ident_after_path_sep(&self, i: usize) -> Option<&str> {
        let t = self.scan.tokens.get(i + 3)?;
        (t.kind == TokKind::Ident).then_some(t.text.as_str())
    }
}
