//! E2 — Table 2: precision layers as configurable memory contracts.
//!
//! The paper's Table 2 is qualitative (format → use case → rationale); we
//! make it quantitative: for each implemented contract we measure the
//! representable range, resolution, worst-case and RMS quantization error
//! over the normalized-embedding regime, and the determinism property
//! (always true — checked, not assumed).

#![forbid(unsafe_code)]

use crate::fixed::{FixedFormat, Q16_16, Q32_32, Q8_24};
use crate::hash::XorShift64;

/// Quantitative row of Table 2.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    pub format: &'static str,
    pub storage_bits: u32,
    pub resolution: f64,
    pub range: f64,
    /// max |x - deq(quant(x))| over the sweep.
    pub max_abs_err: f64,
    /// RMS error over the sweep.
    pub rms_err: f64,
    /// Bit-identical across repeated evaluation (must be true).
    pub deterministic: bool,
    pub use_case: &'static str,
}

fn sweep<F: FixedFormat>(use_case: &'static str, range_hint: f64) -> PrecisionRow {
    let mut rng = XorShift64::new(99);
    let mut max_err = 0f64;
    let mut sum_sq = 0f64;
    const N: usize = 200_000;
    for _ in 0..N {
        // normalized-embedding regime: values in [-1, 1]
        let x = rng.next_f64() * 2.0 - 1.0;
        let q = F::quantize(x);
        let err = (x - F::dequantize(q)).abs();
        max_err = max_err.max(err);
        sum_sq += err * err;
    }
    // determinism: re-quantizing the same sweep gives identical raws
    let mut rng2 = XorShift64::new(123);
    let deterministic = (0..1000).all(|_| {
        let x = rng2.next_f64() * 4.0 - 2.0;
        F::quantize(x) == F::quantize(x)
    });
    PrecisionRow {
        format: F::NAME,
        storage_bits: F::STORAGE_BITS,
        resolution: F::resolution(),
        range: range_hint,
        max_abs_err: max_err,
        rms_err: (sum_sq / N as f64).sqrt(),
        deterministic,
        use_case,
    }
}

/// Compute all Table 2 rows.
pub fn run() -> Vec<PrecisionRow> {
    vec![
        sweep::<Q8_24>("strictly-normalized embeddings", 128.0),
        sweep::<Q16_16>("drones, embedded systems, robotics (paper default)", 32768.0),
        sweep::<Q32_32>("enterprise AI agents / auditability", 2147483648.0),
    ]
}

/// Render in the paper's Table 2 format (+ measured columns).
pub fn print_table(rows: &[PrecisionRow]) {
    println!("\n=== Table 2: Precision Layers as Configurable Contracts ===");
    println!(
        "{:<8} {:>5} {:>12} {:>14} {:>12} {:>12} {:>6}  use case",
        "Format", "bits", "resolution", "range (±)", "max err", "rms err", "det?"
    );
    for r in rows {
        println!(
            "{:<8} {:>5} {:>12.3e} {:>14.0} {:>12.3e} {:>12.3e} {:>6}  {}",
            r.format,
            r.storage_bits,
            r.resolution,
            r.range,
            r.max_abs_err,
            r.rms_err,
            if r.deterministic { "yes" } else { "NO!" },
            r.use_case
        );
    }
    println!("(paper Table 2 lists Q16.16 as the implemented default; Q32.32/Q64.64 as future \
              contracts — we implement Q8.24, Q16.16 and Q32.32.)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_three_formats() {
        let rows = run();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].format, "Q16.16");
    }

    #[test]
    fn all_formats_deterministic() {
        assert!(run().iter().all(|r| r.deterministic));
    }

    #[test]
    fn error_bounded_by_half_resolution() {
        for r in run() {
            assert!(
                r.max_abs_err <= r.resolution / 2.0 + 1e-15,
                "{}: max err {} > res/2 {}",
                r.format,
                r.max_abs_err,
                r.resolution / 2.0
            );
        }
    }

    #[test]
    fn precision_ordering_matches_frac_bits() {
        let rows = run();
        // Q8.24 (24 frac bits) < Q16.16 (16) in error; Q32.32 (32) smallest.
        assert!(rows[0].rms_err < rows[1].rms_err);
        assert!(rows[2].rms_err < rows[0].rms_err);
    }

    #[test]
    fn paper_q16_resolution_claim() {
        // paper §5.1: resolution ≈ 0.000015
        let rows = run();
        assert!((rows[1].resolution - 1.52587890625e-5).abs() < 1e-12);
    }
}
