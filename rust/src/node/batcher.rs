//! Dynamic micro-batcher for the embedding path.
//!
//! The AOT-compiled embedder has a fixed batch shape (B = 8), and the PJRT
//! engine is single-threaded by construction (see [`crate::runtime`]). The
//! batcher is the serving-system answer (vLLM-style): a dedicated model
//! thread owns the embedder; request threads submit texts through a
//! channel; the model thread drains up to B requests or waits at most
//! `window` after the first arrival, then executes one fused batch and
//! fans results back out. Under load, batches fill and throughput
//! approaches B × single-request rate; at low load, latency is bounded by
//! the window.

#![forbid(unsafe_code)]

use crate::runtime::Embedder;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// What the batching loop needs from a model: a fixed batch shape and a
/// batched embed call. The production implementation is the AOT-compiled
/// [`Embedder`]; tests plug in deterministic mocks so the batching logic
/// (fan-in, windowing, fan-out, counters) is exercised without PJRT.
pub trait EmbedBackend {
    /// Fixed batch shape; the loop drains at most this many jobs per call.
    fn batch_size(&self) -> usize;

    /// Embed up to `batch_size` texts, one vector per text, in order.
    fn embed_texts(&self, texts: &[&str]) -> crate::Result<Vec<Vec<f32>>>;
}

impl EmbedBackend for Embedder {
    fn batch_size(&self) -> usize {
        Embedder::batch_size(self)
    }

    fn embed_texts(&self, texts: &[&str]) -> crate::Result<Vec<Vec<f32>>> {
        Embedder::embed_texts(self, texts)
    }
}

/// One in-flight embed request.
struct Job {
    text: String,
    respond: mpsc::Sender<crate::Result<Vec<f32>>>,
}

/// Channel message: a job, or an explicit shutdown (handles may be cloned
/// freely, so sender-drop alone cannot signal termination).
enum Msg {
    Job(Job),
    Shutdown,
}

/// Live batching counters shared with the node's /v1/stats.
#[derive(Debug, Default)]
pub struct BatchCounters {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
}

/// Handle used by request threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Msg>,
    counters: Arc<BatchCounters>,
}

impl BatcherHandle {
    /// Embed one text, blocking until the batch it joins completes.
    pub fn embed(&self, text: &str) -> crate::Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Job(Job { text: text.to_string(), respond: rtx }))
            .map_err(|_| crate::Error::Runtime("batcher is down".into()))?;
        rrx.recv().map_err(|_| crate::Error::Runtime("batcher dropped request".into()))?
    }

    /// Embed several texts (split across batches as needed).
    pub fn embed_many(&self, texts: &[&str]) -> crate::Result<Vec<Vec<f32>>> {
        let mut receivers = Vec::with_capacity(texts.len());
        for t in texts {
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(Msg::Job(Job { text: t.to_string(), respond: rtx }))
                .map_err(|_| crate::Error::Runtime("batcher is down".into()))?;
            receivers.push(rrx);
        }
        receivers
            .into_iter()
            .map(|r| r.recv().map_err(|_| crate::Error::Runtime("batcher dropped".into()))?)
            .collect()
    }

    /// Live batching counters (batches executed, requests served).
    pub fn counters(&self) -> (u64, u64) {
        (self.counters.batches.load(Ordering::Relaxed), self.counters.requests.load(Ordering::Relaxed))
    }
}

/// Statistics snapshot published by the batcher thread.
#[derive(Debug, Default, Clone, Copy)]
pub struct BatchStats {
    pub batches: u64,
    pub requests: u64,
}

/// The batcher: owns the embedder on its own thread.
///
/// PJRT handles are not `Send` (raw pointers), so the embedder is
/// *constructed on* the model thread via the loader closure rather than
/// moved into it.
pub struct EmbedBatcher {
    handle: BatcherHandle,
    thread: Option<std::thread::JoinHandle<BatchStats>>,
}

impl EmbedBatcher {
    /// Spawn the model thread for the production AOT embedder. See
    /// [`Self::start_with_backend`] for the generic machinery.
    pub fn start(
        loader: impl FnOnce() -> crate::Result<Embedder> + Send + 'static,
        window: Duration,
    ) -> crate::Result<Self> {
        Self::start_with_backend(loader, window)
    }

    /// Spawn the model thread; `loader` runs on that thread to build the
    /// backend (PJRT handles never cross threads). Returns Err if loading
    /// fails. `window` bounds added latency at low load.
    pub fn start_with_backend<B: EmbedBackend + 'static>(
        loader: impl FnOnce() -> crate::Result<B> + Send + 'static,
        window: Duration,
    ) -> crate::Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Option<String>>();
        let counters = Arc::new(BatchCounters::default());
        let loop_counters = Arc::clone(&counters);
        let thread = std::thread::Builder::new()
            .name("valori-embed-batcher".into())
            .spawn(move || {
                let embedder = match loader() {
                    Ok(e) => {
                        let _ = ready_tx.send(None);
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Some(e.to_string()));
                        return BatchStats::default();
                    }
                };
                model_loop(embedder, rx, window, &loop_counters)
            })
            .expect("spawn batcher");
        match ready_rx.recv() {
            Ok(None) => Ok(Self { handle: BatcherHandle { tx, counters }, thread: Some(thread) }),
            Ok(Some(msg)) => {
                let _ = thread.join();
                Err(crate::Error::Runtime(format!("embedder load: {msg}")))
            }
            Err(_) => {
                let _ = thread.join();
                Err(crate::Error::Runtime("batcher thread died during load".into()))
            }
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Stop the model thread (explicit shutdown message — handle clones
    /// elsewhere cannot keep the loop alive) and return its stats.
    pub fn stop(mut self) -> BatchStats {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.thread.take().map(|t| t.join().unwrap_or_default()).unwrap_or_default()
    }
}

fn model_loop<B: EmbedBackend>(
    embedder: B,
    rx: mpsc::Receiver<Msg>,
    window: Duration,
    counters: &BatchCounters,
) -> BatchStats {
    let b = embedder.batch_size();
    let mut stats = BatchStats::default();
    loop {
        // Block for the first job of the batch.
        let first = match rx.recv() {
            Ok(Msg::Job(j)) => j,
            Ok(Msg::Shutdown) | Err(_) => return stats,
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + window;
        while jobs.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => jobs.push(j),
                Ok(Msg::Shutdown) => {
                    // serve the in-flight batch, then exit below
                    finish_batch(&embedder, jobs, &mut stats, counters);
                    return stats;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        finish_batch(&embedder, jobs, &mut stats, counters);
    }
}

fn finish_batch<B: EmbedBackend>(
    embedder: &B,
    jobs: Vec<Job>,
    stats: &mut BatchStats,
    counters: &BatchCounters,
) {
    let texts: Vec<&str> = jobs.iter().map(|j| j.text.as_str()).collect();
    stats.batches += 1;
    stats.requests += jobs.len() as u64;
    counters.batches.fetch_add(1, Ordering::Relaxed);
    counters.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
    match embedder.embed_texts(&texts) {
        Ok(vectors) => {
            for (job, v) in jobs.into_iter().zip(vectors) {
                let _ = job.respond.send(Ok(v));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for job in jobs {
                let _ = job.respond.send(Err(crate::Error::Runtime(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir, embedder::Env, Engine};

    fn start_batcher(window_ms: u64) -> Option<EmbedBatcher> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let loader = || {
            let engine = Engine::cpu()?;
            Embedder::load(&engine, artifacts_dir(), Env::A)
        };
        Some(EmbedBatcher::start(loader, Duration::from_millis(window_ms)).unwrap())
    }

    #[test]
    fn single_request_roundtrip() {
        let Some(b) = start_batcher(1) else { return };
        let v = b.handle().embed("Revenue for April").unwrap();
        assert_eq!(v.len(), 128);
        let stats = b.stop();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let Some(b) = start_batcher(50) else { return };
        let h = b.handle();
        let threads: Vec<_> = (0..8)
            .map(|i| {
                let h = h.clone();
                std::thread::spawn(move || {
                    h.embed(&format!("document number {i} about revenue")).unwrap()
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        assert!(results.iter().all(|v| v.len() == 128));
        let stats = b.stop();
        assert_eq!(stats.requests, 8);
        // with a 50ms window, 8 concurrent requests should use few batches
        assert!(stats.batches < 8, "batches = {}", stats.batches);
    }

    #[test]
    fn batched_results_match_unbatched() {
        // batching must not change results (same fixed batch shape is
        // always executed; padding rows are discarded)
        let Some(b) = start_batcher(30) else { return };
        let h = b.handle();
        let solo = h.embed("drone sensor telemetry").unwrap();
        let t1 = {
            let h = h.clone();
            std::thread::spawn(move || h.embed("drone sensor telemetry").unwrap())
        };
        let t2 = {
            let h = h.clone();
            std::thread::spawn(move || h.embed("completely unrelated sentence").unwrap())
        };
        let batched = t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(
            solo.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            batched.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        b.stop();
    }

    #[test]
    fn embed_many_splits_over_batches() {
        let Some(b) = start_batcher(5) else { return };
        let texts: Vec<String> = (0..20).map(|i| format!("text {i}")).collect();
        let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
        let out = b.handle().embed_many(&refs).unwrap();
        assert_eq!(out.len(), 20);
        let stats = b.stop();
        assert_eq!(stats.requests, 20);
        assert!(stats.batches >= 3); // 20 / 8 -> at least 3 batches
    }
}
