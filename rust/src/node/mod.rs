//! The Valori node: HTTP API + request routing + embed batching
//! (paper Fig. 1's interface layer; §5.3 "Node ('std')").
//!
//! The node *wraps* the kernel but never alters its logic: every mutation
//! goes through `Kernel::apply`, is WAL-logged in canonical form, and is
//! observable through `/v1/hash` for replica comparison.
//!
//! ## API
//!
//! | Route | Body | Effect |
//! |---|---|---|
//! | `POST /v1/insert` | `{"id":1,"vector":[...]}` or `{"id":1,"text":"..."}` | insert (text is embedded via the batcher) |
//! | `POST /v1/query` | `{"vector":[...]}` or `{"text":"...","k":10}` | k-NN search |
//! | `POST /v1/delete` | `{"id":1}` | tombstone |
//! | `POST /v1/link` / `unlink` | `{"from":1,"to":2}` | link graph edit |
//! | `POST /v1/meta` | `{"id":1,"key":"k","value":"v"}` | metadata |
//! | `POST /v1/embed` | `{"texts":["..."]}` | embeddings only |
//! | `GET /v1/stats` | — | metrics + kernel info |
//! | `GET /v1/hash` | — | state hash (fnv + sha256) |
//! | `GET /v1/log?from=N` | — | canonical command feed (replication) |
//! | `POST /v1/apply` | `{"commands":["<hex>"...]}` | apply canonical commands (follower ingest) |

pub mod batcher;
pub mod metrics;

pub use batcher::{BatcherHandle, EmbedBatcher};
pub use metrics::Metrics;

use crate::http::{Handler, Request, Response, Server};
use crate::json::{parse, Json};
use crate::snapshot::Snapshot;
use crate::state::{CanonCommand, Command, Kernel};
use crate::wal::WalWriter;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Node configuration.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// HTTP workers.
    pub workers: usize,
    /// Path for the WAL (None = in-memory only).
    pub wal_path: Option<std::path::PathBuf>,
}

impl Default for NodeConfig {
    fn default() -> Self {
        Self { workers: 4, wal_path: None }
    }
}

/// Shared node state behind the HTTP handler.
pub struct NodeState {
    kernel: Mutex<Kernel>,
    /// In-memory canonical log (replication feed + audit).
    log: Mutex<Vec<CanonCommand>>,
    wal: Option<Mutex<WalWriter>>,
    embed: Option<BatcherHandle>,
    pub metrics: Metrics,
}

impl NodeState {
    /// Build node state. If the configured WAL file already exists, the
    /// kernel is **recovered from it first** (replay; torn tail repaired),
    /// then the WAL is opened for append — restart durability.
    pub fn new(
        mut kernel: Kernel,
        config: &NodeConfig,
        embed: Option<BatcherHandle>,
    ) -> crate::Result<Self> {
        let mut log = Vec::new();
        let wal = match &config.wal_path {
            Some(p) => {
                if p.exists() {
                    let rec = crate::wal::recover(p).map_err(|e| {
                        crate::Error::Runtime(format!("wal recovery {p:?}: {e}"))
                    })?;
                    if rec.truncated_tail {
                        crate::wal::truncate_to_valid(p, rec.valid_bytes)?;
                    }
                    for entry in &rec.entries {
                        kernel.apply_canon(&entry.command).map_err(|e| {
                            crate::Error::Runtime(format!(
                                "wal replay: command at seq {} rejected: {e}",
                                entry.seq
                            ))
                        })?;
                        log.push(entry.command.clone());
                    }
                    Some(Mutex::new(WalWriter::append_to(p, rec.entries.len() as u64)?))
                } else {
                    Some(Mutex::new(WalWriter::create(p)?))
                }
            }
            None => None,
        };
        Ok(Self {
            kernel: Mutex::new(kernel),
            log: Mutex::new(log),
            wal,
            embed,
            metrics: Metrics::default(),
        })
    }

    /// Apply an external command: boundary → state machine → log + WAL.
    ///
    /// The log/WAL append happens **while the kernel lock is held**: the
    /// kernel's application order and the logged order must be the same
    /// sequence, or replaying the WAL would reconstruct a different state
    /// (the order *is* the state, paper §3.1).
    pub fn apply(&self, cmd: Command) -> Result<CanonCommand, crate::Error> {
        let mut kernel = self.kernel.lock().expect("kernel poisoned");
        let seq = kernel.seq();
        let canon = kernel.apply(cmd)?;
        self.record(seq, &canon)?;
        Ok(canon)
    }

    /// Apply an already-canonical command (replication ingest path).
    pub fn apply_canon(&self, canon: &CanonCommand) -> Result<(), crate::Error> {
        let mut kernel = self.kernel.lock().expect("kernel poisoned");
        let seq = kernel.seq();
        kernel.apply_canon(canon)?;
        self.record(seq, canon)?;
        Ok(())
    }

    /// Append to the in-memory log + WAL (caller holds the kernel lock).
    fn record(&self, seq: u64, canon: &CanonCommand) -> Result<(), crate::Error> {
        self.log.lock().expect("log poisoned").push(canon.clone());
        if let Some(w) = &self.wal {
            let mut w = w.lock().expect("wal poisoned");
            w.append(seq, canon)?;
            w.flush()?;
        }
        Ok(())
    }

    pub fn with_kernel<T>(&self, f: impl FnOnce(&Kernel) -> T) -> T {
        f(&self.kernel.lock().expect("kernel poisoned"))
    }

    pub fn log_len(&self) -> usize {
        self.log.lock().expect("log poisoned").len()
    }

    pub fn log_slice(&self, from: usize, max: usize) -> Vec<CanonCommand> {
        let log = self.log.lock().expect("log poisoned");
        log.iter().skip(from).take(max).cloned().collect()
    }

    pub fn embedder(&self) -> Option<&BatcherHandle> {
        self.embed.as_ref()
    }
}

/// Start the HTTP server for a node.
pub fn serve(state: Arc<NodeState>, addr: &str, workers: usize) -> std::io::Result<Server> {
    let handler: Handler = Arc::new(move |req| route(&state, req));
    Server::start(addr, workers, handler)
}

fn ok_json(value: Json) -> Response {
    Response::json(200, value.to_string())
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, Json::object(vec![("error", Json::str(msg))]).to_string())
}

/// Route one request (pure function of state + request; exposed for tests).
pub fn route(state: &NodeState, req: Request) -> Response {
    let m = &state.metrics;
    let result = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/insert") => handle_insert(state, &req),
        ("POST", "/v1/insert_batch") => handle_insert_batch(state, &req),
        ("POST", "/v1/query") => handle_query(state, &req),
        ("POST", "/v1/delete") => handle_delete(state, &req),
        ("POST", "/v1/link") => handle_link(state, &req, true),
        ("POST", "/v1/unlink") => handle_link(state, &req, false),
        ("POST", "/v1/meta") => handle_meta(state, &req),
        ("POST", "/v1/embed") => handle_embed(state, &req),
        ("POST", "/v1/apply") => handle_apply(state, &req),
        ("GET", "/v1/stats") => Ok(handle_stats(state)),
        ("GET", "/v1/hash") => Ok(handle_hash(state)),
        ("GET", "/v1/log") => Ok(handle_log(state, &req)),
        ("GET", "/v1/health") => Ok(ok_json(Json::object(vec![("ok", Json::Bool(true))]))),
        _ => Ok(Response::not_found()),
    };
    match result {
        Ok(resp) => resp,
        Err(resp) => {
            Metrics::inc(&m.errors);
            resp
        }
    }
}

type RouteResult = Result<Response, Response>;

fn body_json(req: &Request) -> Result<Json, Response> {
    let text = req.body_str().map_err(|_| Response::bad_request("body is not utf-8"))?;
    parse(text).map_err(|e| Response::bad_request(&format!("invalid json: {e}")))
}

fn get_vector(body: &Json, state: &NodeState) -> Result<Vec<f32>, Response> {
    if let Some(arr) = body.get("vector").as_array() {
        arr.iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| Response::bad_request("vector must be an array of numbers"))
    } else if let Some(text) = body.get("text").as_str() {
        let embed = state
            .embedder()
            .ok_or_else(|| err_json(503, "no embedder loaded (run `make artifacts`)"))?;
        let t0 = Instant::now();
        let v = embed
            .embed(text)
            .map_err(|e| err_json(500, &format!("embed failed: {e}")))?;
        state.metrics.embed_latency.record_us(t0.elapsed().as_micros() as u64);
        Metrics::inc(&state.metrics.embeds);
        Ok(v)
    } else {
        Err(Response::bad_request("need 'vector' or 'text'"))
    }
}

fn state_error_response(e: &crate::Error) -> Response {
    use crate::state::StateError;
    match e {
        crate::Error::State(StateError::DuplicateId(id)) => {
            err_json(409, &format!("duplicate id {id}"))
        }
        crate::Error::State(StateError::UnknownId(id)) => {
            err_json(404, &format!("unknown id {id}"))
        }
        crate::Error::State(se) => err_json(400, &se.to_string()),
        other => err_json(500, &other.to_string()),
    }
}

fn handle_insert(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    let vector = get_vector(&body, state)?;
    state.apply(Command::Insert { id, vector }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.inserts);
    Ok(ok_json(Json::object(vec![
        ("inserted", Json::Int(id as i64)),
        ("seq", Json::Int(state.with_kernel(|k| k.seq()) as i64)),
    ])))
}

fn handle_insert_batch(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let items_json = body
        .get("items")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'items' array of {id, vector}"))?;
    let mut items = Vec::with_capacity(items_json.len());
    for it in items_json {
        let id =
            it.get("id").as_u64().ok_or_else(|| Response::bad_request("item needs 'id'"))?;
        let vector = it
            .get("vector")
            .as_array()
            .ok_or_else(|| Response::bad_request("item needs 'vector'"))?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| Response::bad_request("vector must be numbers"))?;
        items.push((id, vector));
    }
    let n = items.len();
    state.apply(Command::InsertBatch { items }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.inserts);
    Ok(ok_json(Json::object(vec![
        ("inserted", Json::Int(n as i64)),
        ("seq", Json::Int(state.with_kernel(|k| k.seq()) as i64)),
    ])))
}

fn handle_query(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let k = body.get("k").as_u64().unwrap_or(10) as usize;
    let vector = get_vector(&body, state)?;
    let t0 = Instant::now();
    let hits = state
        .with_kernel(|kern| kern.search_f32(&vector, k))
        .map_err(|e| state_error_response(&crate::Error::State(e)))?;
    state.metrics.query_latency.record_us(t0.elapsed().as_micros() as u64);
    Metrics::inc(&state.metrics.queries);
    let hits_json: Vec<Json> = hits
        .iter()
        .map(|h| {
            Json::object(vec![
                ("id", Json::Int(h.id as i64)),
                ("dist_raw", Json::Int(h.dist_raw)),
                ("dist", Json::Float(h.dist)),
            ])
        })
        .collect();
    Ok(ok_json(Json::object(vec![("hits", Json::Array(hits_json))])))
}

fn handle_delete(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    state.apply(Command::Delete { id }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.deletes);
    Ok(ok_json(Json::object(vec![("deleted", Json::Int(id as i64))])))
}

fn handle_link(state: &NodeState, req: &Request, create: bool) -> RouteResult {
    let body = body_json(req)?;
    let from =
        body.get("from").as_u64().ok_or_else(|| Response::bad_request("need numeric 'from'"))?;
    let to = body.get("to").as_u64().ok_or_else(|| Response::bad_request("need numeric 'to'"))?;
    let cmd = if create { Command::Link { from, to } } else { Command::Unlink { from, to } };
    state.apply(cmd).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.links);
    Ok(ok_json(Json::object(vec![("ok", Json::Bool(true))])))
}

fn handle_meta(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    let key = body.get("key").as_str().ok_or_else(|| Response::bad_request("need 'key'"))?;
    let value = body.get("value").as_str().ok_or_else(|| Response::bad_request("need 'value'"))?;
    state
        .apply(Command::SetMeta { id, key: key.to_string(), value: value.to_string() })
        .map_err(|e| state_error_response(&e))?;
    Ok(ok_json(Json::object(vec![("ok", Json::Bool(true))])))
}

fn handle_embed(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let texts = body
        .get("texts")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'texts' array"))?
        .iter()
        .map(|t| t.as_str())
        .collect::<Option<Vec<&str>>>()
        .ok_or_else(|| Response::bad_request("'texts' must be strings"))?;
    let embed =
        state.embedder().ok_or_else(|| err_json(503, "no embedder loaded"))?;
    let vectors = embed.embed_many(&texts).map_err(|e| err_json(500, &e.to_string()))?;
    Metrics::inc(&state.metrics.embeds);
    let arr: Vec<Json> = vectors
        .into_iter()
        .map(|v| Json::Array(v.into_iter().map(|x| Json::Float(x as f64)).collect()))
        .collect();
    Ok(ok_json(Json::object(vec![("embeddings", Json::Array(arr))])))
}

fn handle_apply(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let cmds = body
        .get("commands")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'commands' array of hex strings"))?;
    let mut applied = 0;
    for c in cmds {
        let hex = c.as_str().ok_or_else(|| Response::bad_request("command must be hex string"))?;
        let bytes = hex_decode(hex).ok_or_else(|| Response::bad_request("invalid hex"))?;
        let canon = CanonCommand::from_bytes(&bytes)
            .map_err(|e| Response::bad_request(&format!("bad command: {e}")))?;
        state.apply_canon(&canon).map_err(|e| state_error_response(&e))?;
        applied += 1;
    }
    Ok(ok_json(Json::object(vec![
        ("applied", Json::Int(applied)),
        ("seq", Json::Int(state.with_kernel(|k| k.seq()) as i64)),
        ("hash", Json::str(format!("{:016x}", state.with_kernel(|k| k.state_hash())))),
    ])))
}

fn handle_stats(state: &NodeState) -> Response {
    let (len, seq, dim) =
        state.with_kernel(|k| (k.len(), k.seq(), k.config().dim));
    let mut obj = match state.metrics.to_json() {
        Json::Object(o) => o,
        _ => unreachable!(),
    };
    obj.insert("vectors".into(), Json::Int(len as i64));
    obj.insert("seq".into(), Json::Int(seq as i64));
    obj.insert("dim".into(), Json::Int(dim as i64));
    obj.insert("log_len".into(), Json::Int(state.log_len() as i64));
    if let Some(b) = state.embedder() {
        let (batches, requests) = b.counters();
        obj.insert("batches".into(), Json::Int(batches as i64));
        obj.insert("batched_requests".into(), Json::Int(requests as i64));
    }
    ok_json(Json::Object(obj))
}

fn handle_hash(state: &NodeState) -> Response {
    let snap = state.with_kernel(Snapshot::capture);
    ok_json(Json::object(vec![
        ("fnv", Json::str(format!("{:016x}", snap.fnv))),
        ("sha256", Json::str(snap.sha256_hex())),
        ("seq", Json::Int(state.with_kernel(|k| k.seq()) as i64)),
    ]))
}

fn handle_log(state: &NodeState, req: &Request) -> Response {
    let from = req
        .query
        .as_deref()
        .and_then(|q| {
            q.split('&').find_map(|kv| kv.strip_prefix("from=").and_then(|v| v.parse().ok()))
        })
        .unwrap_or(0usize);
    let cmds = state.log_slice(from, 1000);
    let arr: Vec<Json> =
        cmds.iter().map(|c| Json::str(hex_encode(&c.to_bytes()))).collect();
    ok_json(Json::object(vec![
        ("from", Json::Int(from as i64)),
        ("total", Json::Int(state.log_len() as i64)),
        ("commands", Json::Array(arr)),
    ]))
}

/// Lower-case hex encoding (command wire format for replication).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Hex decoding; None on malformed input.
pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(s.get(i * 2..i * 2 + 2)?, 16).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::KernelConfig;

    fn test_state() -> Arc<NodeState> {
        let kernel = Kernel::new(KernelConfig::default_q16(4));
        Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap())
    }

    fn post(state: &NodeState, path: &str, body: &str) -> (u16, Json) {
        let req = Request {
            method: "POST".into(),
            path: path.into(),
            query: None,
            headers: Default::default(),
            body: body.as_bytes().to_vec(),
        };
        let resp = route(state, req);
        let json = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap_or(Json::Null);
        (resp.status, json)
    }

    fn get(state: &NodeState, path: &str, query: Option<&str>) -> (u16, Json) {
        let req = Request {
            method: "GET".into(),
            path: path.into(),
            query: query.map(|s| s.to_string()),
            headers: Default::default(),
            body: vec![],
        };
        let resp = route(state, req);
        let json = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap_or(Json::Null);
        (resp.status, json)
    }

    #[test]
    fn insert_then_query() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#);
        assert_eq!(st, 200);
        let (st, _) = post(&s, "/v1/insert", r#"{"id":2,"vector":[0.9,0.9,0.9,0.9]}"#);
        assert_eq!(st, 200);
        let (st, body) = post(&s, "/v1/query", r#"{"vector":[0.1,0.2,0.3,0.4],"k":2}"#);
        assert_eq!(st, 200);
        let hits = body.get("hits").as_array().unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].get("id").as_u64(), Some(1));
        assert_eq!(hits[0].get("dist_raw").as_i64(), Some(0));
    }

    #[test]
    fn duplicate_insert_conflicts() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        let (st, body) = post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        assert_eq!(st, 409);
        assert!(body.get("error").as_str().unwrap().contains("duplicate"));
    }

    #[test]
    fn delete_unknown_is_404() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/delete", r#"{"id":99}"#);
        assert_eq!(st, 404);
    }

    #[test]
    fn link_and_meta_flow() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        post(&s, "/v1/insert", r#"{"id":2,"vector":[1,0,0,0]}"#);
        let (st, _) = post(&s, "/v1/link", r#"{"from":1,"to":2}"#);
        assert_eq!(st, 200);
        let (st, _) = post(&s, "/v1/meta", r#"{"id":1,"key":"src","value":"api"}"#);
        assert_eq!(st, 200);
        assert!(s.with_kernel(|k| k.links().has_link(1, 2)));
        let (st, _) = post(&s, "/v1/unlink", r#"{"from":1,"to":2}"#);
        assert_eq!(st, 200);
        assert!(!s.with_kernel(|k| k.links().has_link(1, 2)));
    }

    #[test]
    fn bad_json_is_400() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", "{nope");
        assert_eq!(st, 400);
        let (st, _) = post(&s, "/v1/insert", r#"{"vector":[0,0,0,0]}"#); // no id
        assert_eq!(st, 400);
        let (st, _) = post(&s, "/v1/query", r#"{"k":3}"#); // no vector/text
        assert_eq!(st, 400);
    }

    #[test]
    fn text_without_embedder_is_503() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", r#"{"id":1,"text":"hello"}"#);
        assert_eq!(st, 503);
        let (st, _) = post(&s, "/v1/embed", r#"{"texts":["x"]}"#);
        assert_eq!(st, 503);
    }

    #[test]
    fn stats_and_hash() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0.5,0,0,0]}"#);
        let (st, stats) = get(&s, "/v1/stats", None);
        assert_eq!(st, 200);
        assert_eq!(stats.get("vectors").as_i64(), Some(1));
        assert_eq!(stats.get("inserts").as_i64(), Some(1));
        let (st, hash) = get(&s, "/v1/hash", None);
        assert_eq!(st, 200);
        assert_eq!(hash.get("fnv").as_str().unwrap().len(), 16);
        assert_eq!(hash.get("sha256").as_str().unwrap().len(), 64);
    }

    #[test]
    fn log_feed_and_apply_replicate() {
        let primary = test_state();
        post(&primary, "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#);
        post(&primary, "/v1/insert", r#"{"id":2,"vector":[0.5,0.6,0.7,0.8]}"#);
        post(&primary, "/v1/link", r#"{"from":1,"to":2}"#);

        let (st, feed) = get(&primary, "/v1/log", Some("from=0"));
        assert_eq!(st, 200);
        let cmds = feed.get("commands").as_array().unwrap();
        assert_eq!(cmds.len(), 3);

        // ship to a follower via /v1/apply
        let follower = test_state();
        let body = Json::object(vec![(
            "commands",
            Json::Array(cmds.to_vec()),
        )]);
        let (st, result) = post(&follower, "/v1/apply", &body.to_string());
        assert_eq!(st, 200);
        assert_eq!(result.get("applied").as_i64(), Some(3));

        // paper §9: identical state hashes after processing the same log
        let h_a = primary.with_kernel(|k| k.state_hash());
        let h_b = follower.with_kernel(|k| k.state_hash());
        assert_eq!(h_a, h_b);
    }

    #[test]
    fn hex_roundtrip() {
        let data = vec![0x00, 0xff, 0x12, 0xab];
        assert_eq!(hex_decode(&hex_encode(&data)), Some(data));
        assert_eq!(hex_decode("zz"), None);
        assert_eq!(hex_decode("abc"), None);
        assert_eq!(hex_decode(""), Some(vec![]));
    }

    #[test]
    fn over_http_end_to_end() {
        let s = test_state();
        let server = serve(Arc::clone(&s), "127.0.0.1:0", 2).unwrap();
        let addr = server.addr();
        let body = parse(r#"{"id":5,"vector":[0.1,0.1,0.1,0.1]}"#).unwrap();
        let (st, _) = crate::http::client::post_json(&addr, "/v1/insert", &body).unwrap();
        assert_eq!(st, 200);
        let q = parse(r#"{"vector":[0.1,0.1,0.1,0.1],"k":1}"#).unwrap();
        let (st, resp) = crate::http::client::post_json(&addr, "/v1/query", &q).unwrap();
        assert_eq!(st, 200);
        assert_eq!(resp.get("hits").as_array().unwrap()[0].get("id").as_u64(), Some(5));
        server.stop();
    }
}
