//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.
//! Enough surface for the `valori` binary and the experiment drivers.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty option name '--'".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|next| !next.starts_with("--")) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => {
                s.parse().map_err(|_| format!("option --{name}: cannot parse '{s}'"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--dim=128"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert_eq!(a.opt("dim"), Some("128"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["replay", "my.wal", "other.snap"]);
        assert_eq!(a.subcommand.as_deref(), Some("replay"));
        assert_eq!(a.positional, vec!["my.wal", "other.snap"]);
    }

    #[test]
    fn opt_parse_with_default() {
        let a = parse(&["x", "--k", "10"]);
        assert_eq!(a.opt_parse("k", 5usize).unwrap(), 10);
        assert_eq!(a.opt_parse("missing", 5usize).unwrap(), 5);
        assert!(parse(&["x", "--k", "ten"]).opt_parse("k", 5usize).is_err());
    }

    #[test]
    fn flag_at_end_is_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn option_value_looking_like_subcommand() {
        let a = parse(&["--mode", "serve"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt("mode"), Some("serve"));
    }

    #[test]
    fn empty_args() {
        let a = parse(&[]);
        assert_eq!(a, Args::default());
    }

    #[test]
    fn double_dash_alone_is_error() {
        assert!(Args::parse(vec!["--".to_string()]).is_err());
    }
}
