//! Hand-rolled epoll reactor: the async HTTP front end (Linux only).
//!
//! Zero external dependencies — the three epoll syscalls are declared
//! directly against the libc the binary already links. One event-loop
//! thread owns every socket; a small dispatch pool runs the [`Handler`]
//! so kernel work (searches, WAL-logged mutations) never blocks the loop.
//!
//! ## Per-connection state machine
//!
//! ```text
//! ReadingHeaders → ReadingBody → Dispatching → Writing ─┬→ KeepAlive ─┐
//!        ↑                                              └→ (close)    │
//!        └──────────────────────────────────────────────────────────-─┘
//! ```
//!
//! - `ReadingHeaders`/`ReadingBody`: nonblocking reads feed the
//!   incremental [`RequestParser`]; a parse error answers 400/413 and
//!   closes, exactly like the blocking front end.
//! - `Dispatching`: the parsed request is on the worker pool; bytes that
//!   arrive now are a *pipelined* request, which this server rejects
//!   (one request in flight per connection keeps the dispatch path
//!   trivially order-free: nothing downstream of the socket reorders).
//! - `Writing`: the response (serialized by the same
//!   [`Response::to_bytes`] the blocking path uses) drains through
//!   nonblocking writes, resumed on `EPOLLOUT` edges.
//! - `KeepAlive`: idle between requests; the first byte of the next
//!   request returns to `ReadingHeaders`.
//!
//! Timeouts ride a coarse timer wheel (100 ms ticks): one deadline per
//! connection, reset at request start / dispatch / keep-alive idle, so a
//! slow-loris trickle is evicted `read_timeout` after the request began
//! no matter how many bytes it drips. Shutdown and handler completions
//! wake the loop through a nonblocking socketpair — no self-connection
//! hack, and `stop()` never races the accept loop.
//!
//! ## Why the reactor cannot affect determinism
//!
//! The reactor moves bytes; it never orders kernel work. Each connection
//! has at most one request in flight, the handler runs behind the node's
//! existing `RwLock` exactly as under the blocking front end, and the
//! response bytes are a pure function of the handler's `Response`. The
//! equivalence test drives both front ends with identical request
//! streams and asserts byte-identical responses and identical state
//! hashes.

// R5 allowlisted file (see DETERMINISM.md): the epoll FFI. Every unsafe
// site carries a SAFETY comment; `valori lint` rejects any that does not.
#![allow(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

use super::{
    parse_error_response, Handler, ParsePhase, Request, RequestParser, Response, ServerConfig,
    ServerMetrics, StreamingBody,
};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// epoll FFI (the only unsafe in the crate's I/O layer)

#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    fn close(fd: c_int) -> c_int;
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;
const EPOLLET: u32 = 1 << 31;

/// Thin RAII wrapper over an epoll instance.
struct Epoll {
    fd: c_int,
}

impl Epoll {
    fn new() -> std::io::Result<Self> {
        // SAFETY: epoll_create1 takes no pointers; the returned fd is
        // validated below and owned by this RAII wrapper.
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { fd })
    }

    fn add(&self, fd: RawFd, token: u64, events: u32) -> std::io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: `ev` is a live, properly-aligned repr(C) struct for the
        // duration of the call; the kernel copies it before returning.
        let rc = unsafe { epoll_ctl(self.fd, EPOLL_CTL_ADD, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn del(&self, fd: RawFd) {
        // A dummy event keeps pre-2.6.9 kernels happy; errors are moot
        // because the fd is about to be closed anyway.
        let mut ev = EpollEvent { events: 0, data: 0 };
        // SAFETY: `ev` is a live repr(C) struct for the call; DEL ignores
        // its contents on modern kernels.
        unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Wait for events; EINTR reports as zero events.
    fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> usize {
        let max = events.len() as c_int;
        // SAFETY: the pointer/len pair comes from a live `&mut [EpollEvent]`;
        // the kernel writes at most `max` entries into it.
        let rc = unsafe { epoll_wait(self.fd, events.as_mut_ptr(), max, timeout_ms) };
        if rc < 0 {
            0
        } else {
            rc as usize
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the epoll fd this wrapper owns exclusively;
        // Drop runs once, so it is not closed twice.
        unsafe { close(self.fd) };
    }
}

// ---------------------------------------------------------------------------
// Timer wheel

const TICK_MS: u64 = 100;
const WHEEL_SLOTS: usize = 1024; // ~102 s horizon; longer deadlines re-queue

/// Coarse hashed timer wheel: one lazily-validated entry per connection.
/// Deadline extensions just overwrite `Conn::deadline`; when the stale
/// entry pops, the connection is rescheduled instead of evicted, so
/// refreshing a deadline is O(1) with no wheel traffic.
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>, // (connection slot, generation)
    cursor: usize,
    last_tick: Instant,
}

impl TimerWheel {
    fn new(now: Instant) -> Self {
        Self { slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(), cursor: 0, last_tick: now }
    }

    fn schedule(&mut self, now: Instant, deadline: Instant, token: usize, gen: u64) {
        let ms = deadline.saturating_duration_since(now).as_millis() as u64;
        let ticks = (ms / TICK_MS + 1).clamp(1, WHEEL_SLOTS as u64 - 1) as usize;
        let slot = (self.cursor + ticks) % WHEEL_SLOTS;
        self.slots[slot].push((token, gen));
    }

    /// Milliseconds until the next tick (the epoll wait timeout).
    fn next_timeout_ms(&self, now: Instant) -> i32 {
        let elapsed = now.duration_since(self.last_tick).as_millis() as u64;
        TICK_MS.saturating_sub(elapsed).max(1) as i32
    }

    /// Advance past due ticks, draining candidate entries into `due`.
    fn advance(&mut self, now: Instant, due: &mut Vec<(usize, u64)>) {
        while now.duration_since(self.last_tick).as_millis() as u64 >= TICK_MS {
            self.last_tick += Duration::from_millis(TICK_MS);
            self.cursor = (self.cursor + 1) % WHEEL_SLOTS;
            due.append(&mut self.slots[self.cursor]);
        }
    }
}

// ---------------------------------------------------------------------------
// Connections

/// The connection lifecycle (see the module docs for the transitions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ConnState {
    ReadingHeaders,
    ReadingBody,
    Dispatching,
    Writing,
    KeepAlive,
}

struct Conn {
    stream: TcpStream,
    /// Generation guard: completions and wheel entries carry (slot, gen)
    /// and are dropped when the slot was reused for a newer connection.
    gen: u64,
    state: ConnState,
    parser: RequestParser,
    write_buf: Vec<u8>,
    written: usize,
    /// Streaming response source: refilled into `write_buf` block by
    /// block as the socket drains (backpressure: nothing is pulled while
    /// the socket is full).
    body_stream: Option<StreamingBody>,
    /// Bytes the streaming source still owes against its declared
    /// `content-length`; a source that dries up early tears the
    /// connection (never a silently short 200).
    stream_remaining: u64,
    /// The keep-alive decision for the in-flight response.
    response_keep_alive: bool,
    /// Client sent bytes while a request was already in flight.
    pipelined: bool,
    /// Peer half-closed (EPOLLRDHUP / EOF) while we owe it a response.
    half_closed: bool,
    /// Close once the current write buffer drains (error responses).
    close_after_write: bool,
    /// Requests served on this connection (keep-alive cap).
    served: u32,
    deadline: Instant,
    /// A paced streaming write deferred its next block pull until this
    /// instant (transfer caps); the timer wheel resumes it.
    write_retry_at: Option<Instant>,
}

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKE: u64 = u64::MAX - 1;

/// A parsed request headed for the dispatch pool: (slot, generation,
/// request).
type Job = (usize, u64, Request);
/// A handler result headed back to the loop: (slot, generation,
/// response).
type Completion = (usize, u64, Response);
/// The dispatch pool's shared receiving end.
type JobReceiver = Arc<Mutex<mpsc::Receiver<Job>>>;

// ---------------------------------------------------------------------------
// Public handle

/// Handles for the reactor's threads (owned by [`super::Server`]).
pub(crate) struct ReactorHandle {
    shutdown: Arc<AtomicBool>,
    waker: UnixStream,
    thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl ReactorHandle {
    pub(crate) fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        wake(&self.waker);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        // The reactor thread drops the job sender on exit, which ends the
        // dispatch workers.
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Nudge the event loop (completion posted, shutdown requested). A full
/// pipe means a wake is already pending, so errors are ignorable.
fn wake(waker: &UnixStream) {
    let _ = (&*waker).write_all(&[1]);
}

/// Spawn the event loop + dispatch pool for a bound listener.
pub(crate) fn start(
    listener: TcpListener,
    cfg: ServerConfig,
    handler: Handler,
) -> std::io::Result<ReactorHandle> {
    listener.set_nonblocking(true)?;
    let (wake_tx, wake_rx) = UnixStream::pair()?;
    wake_tx.set_nonblocking(true)?;
    wake_rx.set_nonblocking(true)?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();
    let jobs_rx: JobReceiver = Arc::new(Mutex::new(jobs_rx));

    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for i in 0..cfg.workers.max(1) {
        let jobs_rx = Arc::clone(&jobs_rx);
        let handler = Arc::clone(&handler);
        let completions = Arc::clone(&completions);
        let waker = wake_tx.try_clone()?;
        workers.push(
            std::thread::Builder::new()
                .name(format!("valori-http-{i}"))
                .spawn(move || dispatch_loop(jobs_rx, handler, completions, waker))
                .expect("spawn dispatch worker"),
        );
    }

    let reactor = Reactor {
        epoll: Epoll::new()?,
        listener,
        wake_rx,
        cfg,
        conns: Vec::new(),
        free: Vec::new(),
        open: 0,
        next_gen: 0,
        wheel: TimerWheel::new(Instant::now()),
        jobs: jobs_tx,
        completions,
        shutdown: Arc::clone(&shutdown),
    };
    reactor.epoll.add(reactor.listener.as_raw_fd(), TOKEN_LISTENER, EPOLLIN | EPOLLET)?;
    reactor.epoll.add(reactor.wake_rx.as_raw_fd(), TOKEN_WAKE, EPOLLIN | EPOLLET)?;

    let thread = std::thread::Builder::new()
        .name("valori-http-reactor".into())
        .spawn(move || reactor.run())
        .expect("spawn reactor");

    Ok(ReactorHandle { shutdown, waker: wake_tx, thread: Some(thread), workers })
}

/// Dispatch worker: pull parsed requests, run the handler, post the
/// response back to the loop. Exits when the job channel closes.
fn dispatch_loop(
    jobs: JobReceiver,
    handler: Handler,
    completions: Arc<Mutex<Vec<Completion>>>,
    waker: UnixStream,
) {
    loop {
        let job = {
            let guard = jobs.lock().expect("jobs poisoned");
            guard.recv()
        };
        let Ok((token, gen, req)) = job else { return };
        let resp = handler(req);
        completions.lock().expect("completions poisoned").push((token, gen, resp));
        wake(&waker);
    }
}

// ---------------------------------------------------------------------------
// The event loop

struct Reactor {
    epoll: Epoll,
    listener: TcpListener,
    wake_rx: UnixStream,
    cfg: ServerConfig,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    open: usize,
    next_gen: u64,
    wheel: TimerWheel,
    jobs: mpsc::Sender<Job>,
    completions: Arc<Mutex<Vec<Completion>>>,
    shutdown: Arc<AtomicBool>,
}

impl Reactor {
    fn run(mut self) {
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 256];
        let mut due: Vec<(usize, u64)> = Vec::new();
        let mut scratch = vec![0u8; 16 * 1024];
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let timeout = self.wheel.next_timeout_ms(Instant::now());
            let n = self.epoll.wait(&mut events, timeout);
            let now = Instant::now();
            for ev in &events[..n] {
                let token = ev.data; // copy out of the packed struct
                let flags = ev.events;
                if token == TOKEN_LISTENER {
                    self.accept_ready(now);
                } else if token == TOKEN_WAKE {
                    drain_wake(&self.wake_rx);
                } else {
                    self.conn_event(token as usize, flags, now, &mut scratch);
                }
            }
            self.drain_completions(now);
            due.clear();
            self.wheel.advance(now, &mut due);
            for &(idx, gen) in &due {
                self.check_expiry(idx, gen, now);
            }
        }
        // Teardown: close every connection; dropping `self` closes the
        // listener, the epoll fd and the job sender (ending the workers).
        for idx in 0..self.conns.len() {
            if let Some(conn) = self.conns[idx].take() {
                self.drop_conn(idx, conn);
            }
        }
    }

    fn alloc_slot(&mut self) -> usize {
        if let Some(i) = self.free.pop() {
            i
        } else {
            self.conns.push(None);
            self.conns.len() - 1
        }
    }

    /// Accept until the listener would block (required under EPOLLET).
    fn accept_ready(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.open >= self.cfg.max_connections {
                        // Over the cap: `connections_rejected` only —
                        // `connections_accepted` counts admissions. The
                        // 503 rides the normal nonblocking write path as
                        // a short-lived loop-owned connection (a
                        // synchronous `write_all` on a full send buffer
                        // would hit WouldBlock and close with no
                        // response on the wire).
                        ServerMetrics::add(&self.cfg.metrics.connections_rejected, 1);
                        self.install_rejection(stream, now);
                        continue;
                    }
                    ServerMetrics::add(&self.cfg.metrics.connections_accepted, 1);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let idx = self.alloc_slot();
                    let gen = self.next_gen;
                    self.next_gen += 1;
                    let interest = EPOLLIN | EPOLLOUT | EPOLLET | EPOLLRDHUP;
                    if self.epoll.add(stream.as_raw_fd(), idx as u64, interest).is_err() {
                        self.free.push(idx);
                        continue;
                    }
                    let deadline = now + self.cfg.read_timeout;
                    self.conns[idx] = Some(Conn {
                        stream,
                        gen,
                        state: ConnState::ReadingHeaders,
                        parser: RequestParser::new(),
                        write_buf: Vec::new(),
                        written: 0,
                        body_stream: None,
                        stream_remaining: 0,
                        response_keep_alive: false,
                        pipelined: false,
                        half_closed: false,
                        close_after_write: false,
                        served: 0,
                        deadline,
                        write_retry_at: None,
                    });
                    self.open += 1;
                    ServerMetrics::add(&self.cfg.metrics.connections_open, 1);
                    self.wheel.schedule(now, deadline, idx, gen);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Register an over-cap socket just long enough to deliver its 503
    /// through the nonblocking write machinery, then close. The slot
    /// counts toward `open` while it drains (drop_conn's bookkeeping is
    /// symmetric) and its deadline is the write timeout, so a client
    /// that never reads cannot pin the slot.
    fn install_rejection(&mut self, stream: TcpStream, now: Instant) {
        if stream.set_nonblocking(true).is_err() {
            return; // dropped => closed
        }
        let idx = self.alloc_slot();
        let gen = self.next_gen;
        self.next_gen += 1;
        let interest = EPOLLIN | EPOLLOUT | EPOLLET | EPOLLRDHUP;
        if self.epoll.add(stream.as_raw_fd(), idx as u64, interest).is_err() {
            self.free.push(idx);
            return;
        }
        let resp = Response::json(503, r#"{"error":"too many connections"}"#);
        let deadline = now + self.cfg.write_timeout;
        let mut conn = Conn {
            stream,
            gen,
            state: ConnState::Writing,
            parser: RequestParser::new(),
            write_buf: resp.to_bytes(false),
            written: 0,
            body_stream: None,
            stream_remaining: 0,
            response_keep_alive: false,
            pipelined: false,
            half_closed: false,
            close_after_write: true,
            served: 0,
            deadline,
            write_retry_at: None,
        };
        self.open += 1;
        ServerMetrics::add(&self.cfg.metrics.connections_open, 1);
        self.wheel.schedule(now, deadline, idx, gen);
        if self.flush_write(idx, &mut conn, now) {
            self.drop_conn(idx, conn);
        } else {
            self.conns[idx] = Some(conn);
        }
    }

    /// One epoll event for a connection slot.
    fn conn_event(&mut self, idx: usize, flags: u32, now: Instant, scratch: &mut [u8]) {
        let Some(slot) = self.conns.get_mut(idx) else { return };
        let Some(mut conn) = slot.take() else { return };
        let mut close = flags & (EPOLLERR | EPOLLHUP) != 0;
        if !close && flags & EPOLLIN != 0 {
            close = self.readable(idx, &mut conn, now, scratch);
        }
        if !close
            && flags & EPOLLOUT != 0
            && conn.state == ConnState::Writing
            && conn.write_retry_at.is_none()
        {
            close = self.flush_write(idx, &mut conn, now);
        }
        if !close && flags & EPOLLRDHUP != 0 {
            // Peer finished sending. If no response is owed, we're done;
            // otherwise finish the in-flight response, then close.
            if matches!(conn.state, ConnState::Dispatching | ConnState::Writing) {
                conn.half_closed = true;
            } else {
                close = true;
            }
        }
        if close {
            self.drop_conn(idx, conn);
        } else {
            self.conns[idx] = Some(conn);
        }
    }

    /// Drain the socket (required under EPOLLET). Returns true when the
    /// connection must close.
    fn readable(&mut self, idx: usize, conn: &mut Conn, now: Instant, scratch: &mut [u8]) -> bool {
        loop {
            match conn.stream.read(scratch) {
                Ok(0) => {
                    // EOF. Deliver any in-flight response first (the peer
                    // may only have shut down its write side).
                    if matches!(conn.state, ConnState::Dispatching | ConnState::Writing) {
                        conn.half_closed = true;
                        return false;
                    }
                    // Truncated requests resolve to the blocking front
                    // end's exact wire behavior (serve / 400 / silence).
                    match conn.parser.finish_eof() {
                        Ok(Some(req)) => {
                            conn.half_closed = true;
                            return self.admit_or_dispatch(idx, conn, req, now);
                        }
                        Ok(None) => return true,
                        Err(err) => {
                            let Some(resp) = parse_error_response(&err) else { return true };
                            conn.write_buf = resp.to_bytes(false);
                            conn.written = 0;
                            conn.state = ConnState::Writing;
                            conn.response_keep_alive = false;
                            conn.close_after_write = true;
                            return self.flush_write(idx, conn, now);
                        }
                    }
                }
                Ok(n) => {
                    match conn.state {
                        ConnState::Dispatching | ConnState::Writing => {
                            // A request is already in flight: these bytes
                            // are a pipelined request. Note and discard;
                            // the rejection is written after the current
                            // response drains.
                            conn.pipelined = true;
                            continue;
                        }
                        ConnState::KeepAlive => {
                            conn.state = ConnState::ReadingHeaders;
                            conn.deadline = now + self.cfg.read_timeout;
                        }
                        _ => {}
                    }
                    match conn.parser.feed(&scratch[..n]) {
                        Ok(Some(req)) => {
                            if conn.parser.buffered() > 0 {
                                conn.pipelined = true;
                            }
                            if self.admit_or_dispatch(idx, conn, req, now) {
                                return true;
                            }
                            continue; // keep draining (ET)
                        }
                        Ok(None) => {
                            conn.state = match conn.parser.phase() {
                                ParsePhase::Headers => ConnState::ReadingHeaders,
                                ParsePhase::Body => ConnState::ReadingBody,
                            };
                            continue;
                        }
                        Err(err) => {
                            let Some(resp) = parse_error_response(&err) else { return true };
                            conn.write_buf = resp.to_bytes(false);
                            conn.written = 0;
                            conn.state = ConnState::Writing;
                            conn.response_keep_alive = false;
                            conn.close_after_write = true;
                            return self.flush_write(idx, conn, now);
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
    }

    /// Queue a parsed request to the dispatch pool — unless the
    /// admission hook rejects it, in which case the rejection is
    /// installed as a normal response (same wire bytes and keep-alive
    /// semantics as the blocking front end) without ever occupying a
    /// dispatch worker. Returns true when the connection must close.
    fn admit_or_dispatch(&mut self, idx: usize, conn: &mut Conn, req: Request, now: Instant) -> bool {
        conn.state = ConnState::Dispatching;
        conn.deadline = now + self.cfg.write_timeout;
        conn.response_keep_alive = req.wants_keep_alive();
        if let Some(resp) = self.cfg.admission.as_ref().and_then(|a| a(&req)) {
            // Mirror drain_completions' keep-alive decision so a
            // rejection and a served response behave identically on the
            // wire (and both count toward requests_served).
            let keep = conn.response_keep_alive
                && conn.served + 1 < self.cfg.max_requests_per_conn
                && !conn.pipelined;
            conn.write_buf = resp.to_bytes(keep);
            conn.written = 0;
            conn.response_keep_alive = keep;
            conn.state = ConnState::Writing;
            return self.flush_write(idx, conn, now);
        }
        let _ = self.jobs.send((idx, conn.gen, req));
        false
    }

    /// Write until done or the socket would block. Returns true when the
    /// connection must close.
    fn flush_write(&mut self, idx: usize, conn: &mut Conn, now: Instant) -> bool {
        loop {
            if conn.written == conn.write_buf.len() {
                // Streaming body: refill from the source before treating
                // the response as complete. One block in memory at a
                // time; the pull happens only when the previous block is
                // fully on the wire, so a slow client throttles the
                // producer instead of ballooning the buffer.
                if let Some(sb) = conn.body_stream.clone() {
                    if let Some(wait) = sb.defer_for() {
                        // Transfer-capped stream: postpone the next pull
                        // by re-arming the timer wheel — never by
                        // blocking the event loop. The deadline extends
                        // past the pause so pacing cannot trip the
                        // write timeout.
                        let resume = now + wait;
                        conn.write_retry_at = Some(resume);
                        conn.deadline = resume + self.cfg.write_timeout;
                        self.wheel.schedule(now, resume, idx, conn.gen);
                        return false;
                    }
                    match sb.next_block() {
                        Some(block) if !block.is_empty() => {
                            if block.len() as u64 > conn.stream_remaining {
                                return true; // source overran its declared length
                            }
                            conn.stream_remaining -= block.len() as u64;
                            conn.write_buf = block;
                            conn.written = 0;
                            // The write budget is per block for streams:
                            // each drained block proves progress, while a
                            // stalled client still times out one
                            // `write_timeout` after its last block.
                            conn.deadline = now + self.cfg.write_timeout;
                            continue;
                        }
                        // An empty block violates the source contract;
                        // tearing beats spinning the event loop on it.
                        Some(_) => return true,
                        None => {
                            let torn = conn.stream_remaining > 0;
                            conn.body_stream = None;
                            if torn {
                                // Aborted mid-stream: the client already
                                // saw the full content-length header, so
                                // the only honest signal is a short body
                                // + close.
                                return true;
                            }
                        }
                    }
                }
                // Response fully on the wire. Parse-error and
                // pipeline-rejection responses carry `close_after_write`
                // and are not counted — the blocking path only counts
                // successfully parsed, handled requests.
                if !conn.close_after_write {
                    ServerMetrics::add(&self.cfg.metrics.requests_served, 1);
                }
                conn.served += 1;
                if conn.close_after_write || conn.half_closed {
                    return true;
                }
                if conn.pipelined {
                    // Reject the pipelined request explicitly, then close.
                    conn.pipelined = false;
                    ServerMetrics::add(&self.cfg.metrics.pipelined_rejected, 1);
                    conn.parser = RequestParser::new();
                    conn.write_buf =
                        Response::bad_request("pipelining not supported").to_bytes(false);
                    conn.written = 0;
                    conn.close_after_write = true;
                    continue;
                }
                if !conn.response_keep_alive {
                    return true;
                }
                conn.state = ConnState::KeepAlive;
                conn.write_buf.clear();
                conn.written = 0;
                conn.deadline = now + self.cfg.read_timeout;
                return false;
            }
            match conn.stream.write(&conn.write_buf[conn.written..]) {
                Ok(0) => return true,
                Ok(n) => conn.written += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
    }

    /// Move finished handler responses onto their connections.
    fn drain_completions(&mut self, now: Instant) {
        let done: Vec<Completion> =
            std::mem::take(&mut *self.completions.lock().expect("completions poisoned"));
        for (idx, gen, resp) in done {
            let Some(slot) = self.conns.get_mut(idx) else { continue };
            let Some(mut conn) = slot.take() else { continue };
            if conn.gen != gen || conn.state != ConnState::Dispatching {
                // The connection this response belonged to is gone (slot
                // reused or state reset); drop the response.
                self.conns[idx] = Some(conn);
                continue;
            }
            // `half_closed` is deliberately NOT part of the header
            // decision: the blocking path derives the header purely from
            // the request (then discovers EOF on its next read), and the
            // write path below still closes half-closed connections
            // after the response drains.
            let keep = conn.response_keep_alive
                && conn.served + 1 < self.cfg.max_requests_per_conn
                && !conn.pipelined;
            if let Some(sb) = resp.stream.clone() {
                conn.write_buf = resp.head_bytes(keep);
                conn.stream_remaining = sb.content_length;
                conn.body_stream = Some(sb);
            } else {
                conn.write_buf = resp.to_bytes(keep);
            }
            conn.written = 0;
            conn.response_keep_alive = keep;
            conn.state = ConnState::Writing;
            conn.deadline = now + self.cfg.write_timeout;
            if self.flush_write(idx, &mut conn, now) {
                self.drop_conn(idx, conn);
            } else {
                self.conns[idx] = Some(conn);
            }
        }
    }

    /// A wheel entry popped: evict if actually past deadline, otherwise
    /// re-queue at the (possibly extended) deadline.
    fn check_expiry(&mut self, idx: usize, gen: u64, now: Instant) {
        let Some(slot) = self.conns.get_mut(idx) else { return };
        let Some(conn) = slot.as_ref() else { return };
        if conn.gen != gen {
            return; // slot reused by a newer connection
        }
        // A paced streaming write parked a resume point (pacing pushed
        // the deadline past it, so this check comes first).
        if let Some(at) = conn.write_retry_at {
            if conn.state == ConnState::Writing {
                if now < at {
                    self.wheel.schedule(now, at, idx, gen);
                    return;
                }
                let mut conn = slot.take().expect("checked above");
                conn.write_retry_at = None;
                if self.flush_write(idx, &mut conn, now) {
                    self.drop_conn(idx, conn);
                    return;
                }
                self.conns[idx] = Some(conn);
                // This pop consumed the connection's wheel entry; keep
                // exactly one alive unless flush_write re-armed a pause
                // (which scheduled its own).
                let (deadline, paused) = match self.conns[idx].as_ref() {
                    Some(c) => (c.deadline, c.write_retry_at.is_some()),
                    None => return,
                };
                if !paused {
                    self.wheel.schedule(now, deadline, idx, gen);
                }
                return;
            }
        }
        if now >= conn.deadline {
            ServerMetrics::add(&self.cfg.metrics.connections_timed_out, 1);
            let conn = slot.take().expect("checked above");
            self.drop_conn(idx, conn);
        } else {
            let deadline = conn.deadline;
            self.wheel.schedule(now, deadline, idx, gen);
        }
    }

    /// Deregister + close; the slot was already vacated by the caller.
    fn drop_conn(&mut self, idx: usize, conn: Conn) {
        self.epoll.del(conn.stream.as_raw_fd());
        drop(conn);
        self.free.push(idx);
        self.open -= 1;
        self.cfg.metrics.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Empty the wake pipe (edge-triggered: must drain fully).
fn drain_wake(rx: &UnixStream) {
    let mut buf = [0u8; 64];
    loop {
        match (&*rx).read(&mut buf) {
            Ok(0) => return,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
}
