//! The AOT-compiled embedding model, executed from Rust.
//!
//! Loads `embedder_enva.hlo.txt` (or env B for the divergence experiments)
//! plus the exported weights, and serves `embed_batch` on fixed-shape
//! batches. Weights are uploaded once as literals and reused across calls.

#![forbid(unsafe_code)]

use super::engine::{literal_f32, literal_i32, Engine, LoadedComputation};
use super::manifest::Manifest;
use super::xla_stub as xla;
use crate::tokenizer::Tokenizer;
use crate::Error;
use std::path::Path;

/// Which simulated environment's lowering to load (Table 1 / DESIGN §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Env {
    /// Pallas attention + sum pooling (the default runtime model).
    A,
    /// jnp attention + cumsum pooling (the "other machine").
    B,
}

impl Env {
    pub fn artifact(&self) -> &'static str {
        match self {
            Env::A => "embedder_enva.hlo.txt",
            Env::B => "embedder_envb.hlo.txt",
        }
    }
}

/// Compiled embedder + weights + tokenizer.
pub struct Embedder {
    comp: LoadedComputation,
    weights: Vec<xla::Literal>,
    tokenizer: Tokenizer,
    pub manifest: Manifest,
    pub env: Env,
}

impl Embedder {
    /// Load the embedder for `env` from the artifacts directory.
    pub fn load(engine: &Engine, artifacts_dir: impl AsRef<Path>, env: Env) -> crate::Result<Self> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let comp = engine.load_hlo(dir.join(env.artifact()))?;
        let mut weights = Vec::with_capacity(manifest.params.len());
        for spec in &manifest.params {
            let data = manifest.load_weight(dir, spec)?;
            weights.push(literal_f32(&data, &spec.shape)?);
        }
        let tokenizer =
            Tokenizer::new(manifest.model.vocab as u32, manifest.model.seq_len);
        Ok(Self { comp, weights, tokenizer, manifest, env })
    }

    /// Model batch size (inputs are padded up to this).
    pub fn batch_size(&self) -> usize {
        self.manifest.model.batch
    }

    /// Embedding dimensionality.
    pub fn dim(&self) -> usize {
        self.manifest.model.d_model
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// Embed up to `batch_size` texts; returns one `dim`-length vector per
    /// input text (padding rows are dropped).
    pub fn embed_texts(&self, texts: &[&str]) -> crate::Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        if texts.len() > b {
            return Err(Error::Runtime(format!(
                "batch overflow: {} texts > model batch {b}",
                texts.len()
            )));
        }
        let ids = self.tokenizer.encode_batch(texts, b);
        self.embed_token_ids(&ids, texts.len())
    }

    /// Embed pre-tokenized ids (row-major `[batch, seq_len]`, padded).
    pub fn embed_token_ids(&self, ids: &[i32], n_real: usize) -> crate::Result<Vec<Vec<f32>>> {
        let m = &self.manifest.model;
        assert_eq!(ids.len(), m.batch * m.seq_len, "ids must be a full batch");
        let ids_lit = literal_i32(ids, &[m.batch, m.seq_len])?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&ids_lit);
        let out = self.comp.run_borrowed(&args)?;
        let flat =
            out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("embedder output: {e}")))?;
        debug_assert_eq!(flat.len(), m.batch * m.d_model);
        Ok(flat.chunks(m.d_model).take(n_real).map(|c| c.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    fn load(env: Env) -> Option<Embedder> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        let engine = Engine::cpu().unwrap();
        Some(Embedder::load(&engine, artifacts_dir(), env).unwrap())
    }

    #[test]
    fn embeds_texts_to_unit_vectors() {
        let Some(e) = load(Env::A) else { return };
        let out = e.embed_texts(&["Revenue for April", "drone sensor telemetry"]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), e.dim());
        for v in &out {
            let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-3, "norm = {n}");
        }
    }

    #[test]
    fn embedding_is_run_to_run_deterministic() {
        let Some(e) = load(Env::A) else { return };
        let a = e.embed_texts(&["What is the profit in April?"]).unwrap();
        let b = e.embed_texts(&["What is the profit in April?"]).unwrap();
        // same binary, same host, same lowering => bit-identical
        assert_eq!(
            a[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn env_a_and_env_b_diverge_at_bit_level() {
        // Table 1's mechanism through the full AOT+PJRT stack.
        let Some(ea) = load(Env::A) else { return };
        let Some(eb) = load(Env::B) else { return };
        let texts = ["Revenue for April"];
        let va = &ea.embed_texts(&texts).unwrap()[0];
        let vb = &eb.embed_texts(&texts).unwrap()[0];
        let diff = va
            .iter()
            .zip(vb)
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        assert!(diff > va.len() / 2, "only {diff}/{} dims diverged", va.len());
        // yet semantically near-identical (paper: cosine > 0.9999)
        let dot: f64 = va.iter().zip(vb).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!(dot > 0.9999, "cosine = {dot}");
    }

    #[test]
    fn similar_texts_are_closer_than_unrelated() {
        let Some(e) = load(Env::A) else { return };
        let out = e
            .embed_texts(&[
                "Revenue for April",
                "April financial summary revenue",
                "drone lidar waypoint altitude telemetry",
            ])
            .unwrap();
        let cos = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let sim_related = cos(&out[0], &out[1]);
        let sim_unrelated = cos(&out[0], &out[2]);
        assert!(
            sim_related > sim_unrelated,
            "related {sim_related} vs unrelated {sim_unrelated}"
        );
    }

    #[test]
    fn batch_overflow_is_error() {
        let Some(e) = load(Env::A) else { return };
        let texts: Vec<&str> = (0..e.batch_size() + 1).map(|_| "x").collect();
        assert!(e.embed_texts(&texts).is_err());
    }
}
