//! Bench/driver for **§8.2** — raw retrieval latency. Paper claim:
//! "< 500 µs for typical k-NN queries" (10k-scale memory, MacBook M3).
//!
//! Run: `cargo bench --bench knn_latency`

use valori::bench::BenchConfig;
use valori::experiments::latency;

fn main() {
    let quick = std::env::var("VALORI_BENCH_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let n = if quick { 2000 } else { 10_000 };

    // The paper's workload.
    let r = latency::run(n, 128, 10, &cfg);
    latency::print_result(&r);

    // Scaling sweep: where does the 500 µs budget run out?
    println!("\nlatency scaling sweep (Q16.16 HNSW, k=10, dim 128):");
    let sizes: &[usize] = if quick { &[1000, 5000] } else { &[1000, 5000, 10_000, 20_000, 50_000] };
    for &size in sizes {
        let r = latency::run(size, 128, 10, &BenchConfig::quick());
        println!(
            "  n={size:>6}  p50 {}  p99 {}  (<500µs: {})",
            valori::bench::fmt_ns(r.hnsw_q16.p50_ns),
            valori::bench::fmt_ns(r.hnsw_q16.p99_ns),
            r.hnsw_q16.p50_ns < 500_000.0
        );
    }

    // k sweep at the paper's scale.
    println!("\nk sweep at n={n} (Q16.16 HNSW):");
    for k in [1usize, 10, 50, 100] {
        let r = latency::run(n.min(10_000), 128, k, &BenchConfig::quick());
        println!("  k={k:>4}  p50 {}", valori::bench::fmt_ns(r.hnsw_q16.p50_ns));
    }
}
