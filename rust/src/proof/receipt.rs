//! State receipts, membership proofs, and the shared offline verifier.
//!
//! A **receipt** is the signable summary of one collection's state at one
//! logical instant: `{state_version, seq, snapshot_hash, wal_hash,
//! merkle_root}` plus the per-shard Merkle roots the combined root folds
//! over. `snapshot_hash` pins the canonical snapshot byte stream (SHA-256
//! fold, [`crate::snapshot`]), `wal_hash` is the advisory FNV fold over the
//! canonical command logs, and `merkle_root` is the proof-carrying root.
//!
//! A **membership proof** ties one record to a receipt: the record's
//! canonical leaf encoding plus the sibling path from its slot to its
//! shard root. [`verify_membership`] checks the whole chain — leaf →
//! shard root → combined root — with `log2(capacity) + 1` hashes and no
//! access to the node or its state. The same function backs
//! `valori verify` and the test suite, so the CLI can never drift from
//! what the tests pin.

#![forbid(unsafe_code)]

use super::leaf;
use super::tree::{combined_root, fold_path};
use crate::hash::{hex_lower, hex_to_bytes, hex_to_digest};
use crate::json::Json;
use std::fmt;

/// Signable state summary for one collection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Receipt {
    /// Snapshot format version (quantized collections use a distinct one).
    pub state_version: u32,
    /// Logical clock: number of successfully applied commands.
    pub seq: u64,
    /// SHA-256 fold over the per-shard canonical snapshot digests.
    pub snapshot_hash: [u8; 32],
    /// Advisory FNV-1a 64 fold over the per-shard canonical command logs.
    pub wal_hash: u64,
    /// Combined Merkle root ([`combined_root`] over `shard_roots`).
    pub merkle_root: [u8; 32],
    /// Per-shard Merkle roots, shard order.
    pub shard_roots: Vec<[u8; 32]>,
}

/// Proof that one record is part of a receipt's `merkle_root`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipProof {
    pub id: u64,
    /// Owning shard (`splitmix64(id) % n_shards`, the canonical routing).
    pub shard: u64,
    /// Arena slot inside the shard.
    pub slot: u64,
    /// Shard tree capacity (power of two; fixes the path length).
    pub capacity: u64,
    /// Canonical leaf encoding ([`crate::proof::leaf`]).
    pub record: Vec<u8>,
    /// Sibling digests, bottom-up.
    pub path: Vec<[u8; 32]>,
}

/// Closed verification-failure taxonomy (shared by CLI exit codes, tests,
/// and error messages).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyError {
    /// `combined_root(shard_roots) != merkle_root` — receipt is internally
    /// inconsistent.
    CombinedRootMismatch,
    /// Proof's shard index is outside the receipt's shard list.
    ShardOutOfRange,
    /// Capacity is not a power of two or path length != log2(capacity).
    PathShape,
    /// Slot index is outside the claimed capacity.
    SlotOutOfRange,
    /// Leaf encoding does not parse canonically.
    BadLeaf(leaf::LeafError),
    /// Leaf parses but carries a different record id than claimed.
    IdMismatch,
    /// Folded path does not reproduce the shard root.
    RootMismatch,
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::CombinedRootMismatch => {
                f.write_str("shard roots do not fold to the receipt merkle_root")
            }
            VerifyError::ShardOutOfRange => f.write_str("proof shard outside receipt shard list"),
            VerifyError::PathShape => f.write_str("sibling path length does not match capacity"),
            VerifyError::SlotOutOfRange => f.write_str("slot outside claimed tree capacity"),
            VerifyError::BadLeaf(e) => write!(f, "leaf encoding invalid: {e}"),
            VerifyError::IdMismatch => f.write_str("leaf id differs from claimed id"),
            VerifyError::RootMismatch => f.write_str("folded path does not match shard root"),
        }
    }
}

/// Check a receipt's internal consistency: the per-shard roots must fold
/// to the combined `merkle_root`.
pub fn verify_receipt(receipt: &Receipt) -> Result<(), VerifyError> {
    if combined_root(&receipt.shard_roots) != receipt.merkle_root {
        return Err(VerifyError::CombinedRootMismatch);
    }
    Ok(())
}

/// Offline membership verification: leaf encoding → shard root → combined
/// root. Rejects any single-bit tamper in the leaf, the path, the claimed
/// position, or the receipt itself.
pub fn verify_membership(proof: &MembershipProof, receipt: &Receipt) -> Result<(), VerifyError> {
    verify_receipt(receipt)?;
    let shard = proof.shard as usize;
    if shard >= receipt.shard_roots.len() {
        return Err(VerifyError::ShardOutOfRange);
    }
    if proof.capacity == 0 || !proof.capacity.is_power_of_two() {
        return Err(VerifyError::PathShape);
    }
    if proof.path.len() != proof.capacity.trailing_zeros() as usize {
        return Err(VerifyError::PathShape);
    }
    if proof.slot >= proof.capacity {
        return Err(VerifyError::SlotOutOfRange);
    }
    let rec = leaf::decode(&proof.record).map_err(VerifyError::BadLeaf)?;
    if rec.id != proof.id {
        return Err(VerifyError::IdMismatch);
    }
    let folded = fold_path(&proof.record, proof.slot as usize, &proof.path);
    if folded != receipt.shard_roots[shard] {
        return Err(VerifyError::RootMismatch);
    }
    Ok(())
}

impl Receipt {
    /// Canonical JSON shape served by `GET /v2/collections/{name}/proof`.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("state_version", Json::Int(self.state_version as i64)),
            ("seq", Json::Int(self.seq as i64)),
            ("snapshot_hash", Json::str(hex_lower(&self.snapshot_hash))),
            ("wal_hash", Json::str(format!("{:016x}", self.wal_hash))),
            ("merkle_root", Json::str(hex_lower(&self.merkle_root))),
            (
                "shards",
                Json::Array(self.shard_roots.iter().map(|r| Json::str(hex_lower(r))).collect()),
            ),
        ])
    }

    /// Parse the wire shape back. `None` on any missing/ill-typed field.
    pub fn from_json(j: &Json) -> Option<Self> {
        let shard_roots = j
            .get("shards")
            .as_array()?
            .iter()
            .map(|s| hex_to_digest(s.as_str()?))
            .collect::<Option<Vec<_>>>()?;
        Some(Self {
            state_version: u32::try_from(j.get("state_version").as_u64()?).ok()?,
            seq: j.get("seq").as_u64()?,
            snapshot_hash: hex_to_digest(j.get("snapshot_hash").as_str()?)?,
            wal_hash: u64::from_str_radix(j.get("wal_hash").as_str()?, 16).ok()?,
            merkle_root: hex_to_digest(j.get("merkle_root").as_str()?)?,
            shard_roots,
        })
    }
}

impl MembershipProof {
    /// Canonical JSON shape served by `GET …/proof?id=N`.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("id", Json::Int(self.id as i64)),
            ("shard", Json::Int(self.shard as i64)),
            ("slot", Json::Int(self.slot as i64)),
            ("capacity", Json::Int(self.capacity as i64)),
            ("record", Json::str(hex_lower(&self.record))),
            ("path", Json::Array(self.path.iter().map(|h| Json::str(hex_lower(h))).collect())),
        ])
    }

    /// Parse the wire shape back. `None` on any missing/ill-typed field.
    pub fn from_json(j: &Json) -> Option<Self> {
        let path = j
            .get("path")
            .as_array()?
            .iter()
            .map(|s| hex_to_digest(s.as_str()?))
            .collect::<Option<Vec<_>>>()?;
        Some(Self {
            id: j.get("id").as_u64()?,
            shard: j.get("shard").as_u64()?,
            slot: j.get("slot").as_u64()?,
            capacity: j.get("capacity").as_u64()?,
            record: hex_to_bytes(j.get("record").as_str()?)?,
            path,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proof::tree::MerkleTree;

    /// Two-shard fixture: shard 0 holds ids {0, 2}, shard 1 holds id {1}.
    fn fixture() -> (Receipt, MembershipProof) {
        let enc0 = leaf::encode_live(0, &[65536, 0], None, &[2]);
        let enc2 = leaf::encode_live(2, &[0, -65536], None, &[]);
        let enc1 = leaf::encode_live(1, &[1, 2], None, &[]);
        let mut t0 = MerkleTree::new();
        t0.set_leaf(0, &enc0);
        t0.set_leaf(1, &enc2);
        let mut t1 = MerkleTree::new();
        t1.set_leaf(0, &enc1);
        let shard_roots = vec![t0.root(), t1.root()];
        let receipt = Receipt {
            state_version: 2,
            seq: 3,
            snapshot_hash: [0xaa; 32],
            wal_hash: 0x1234_5678_9abc_def0,
            merkle_root: combined_root(&shard_roots),
            shard_roots,
        };
        let proof = MembershipProof {
            id: 2,
            shard: 0,
            slot: 1,
            capacity: t0.capacity() as u64,
            record: enc2,
            path: t0.proof_path(1).unwrap(),
        };
        (receipt, proof)
    }

    #[test]
    fn valid_proof_verifies() {
        let (receipt, proof) = fixture();
        assert_eq!(verify_receipt(&receipt), Ok(()));
        assert_eq!(verify_membership(&proof, &receipt), Ok(()));
    }

    #[test]
    fn every_single_bit_tamper_is_rejected() {
        let (receipt, proof) = fixture();

        let mut p = proof.clone();
        p.record[10] ^= 1;
        assert!(verify_membership(&p, &receipt).is_err());

        let mut p = proof.clone();
        p.path[0][31] ^= 1;
        assert_eq!(verify_membership(&p, &receipt), Err(VerifyError::RootMismatch));

        let mut p = proof.clone();
        p.slot = 0;
        assert!(verify_membership(&p, &receipt).is_err());

        let mut p = proof.clone();
        p.id = 3;
        assert_eq!(verify_membership(&p, &receipt), Err(VerifyError::IdMismatch));

        let mut p = proof.clone();
        p.shard = 5;
        assert_eq!(verify_membership(&p, &receipt), Err(VerifyError::ShardOutOfRange));

        let mut p = proof.clone();
        p.capacity = 3;
        assert_eq!(verify_membership(&p, &receipt), Err(VerifyError::PathShape));

        let mut r = receipt.clone();
        r.merkle_root[0] ^= 1;
        assert_eq!(verify_membership(&proof, &r), Err(VerifyError::CombinedRootMismatch));

        let mut r = receipt.clone();
        r.shard_roots[1][0] ^= 1;
        assert_eq!(verify_membership(&proof, &r), Err(VerifyError::CombinedRootMismatch));
    }

    #[test]
    fn receipt_json_roundtrip() {
        let (receipt, proof) = fixture();
        let r2 = Receipt::from_json(&receipt.to_json()).unwrap();
        assert_eq!(receipt, r2);
        let p2 = MembershipProof::from_json(&proof.to_json()).unwrap();
        assert_eq!(proof, p2);
        // parse survives a serialize->parse cycle through text
        let text = receipt.to_json().to_string();
        let parsed = crate::json::parse(&text).unwrap();
        assert_eq!(Receipt::from_json(&parsed).unwrap(), receipt);
        assert!(Receipt::from_json(&Json::Null).is_none());
        assert!(MembershipProof::from_json(&Json::Int(3)).is_none());
    }

    #[test]
    fn tombstone_membership_verifies() {
        let enc = leaf::encode_tombstone(7);
        let mut t = MerkleTree::new();
        t.set_leaf(0, &enc);
        let shard_roots = vec![t.root()];
        let receipt = Receipt {
            state_version: 2,
            seq: 2,
            snapshot_hash: [0; 32],
            wal_hash: 0,
            merkle_root: combined_root(&shard_roots),
            shard_roots,
        };
        let proof = MembershipProof {
            id: 7,
            shard: 0,
            slot: 0,
            capacity: 1,
            record: enc,
            path: vec![],
        };
        assert_eq!(verify_membership(&proof, &receipt), Ok(()));
    }
}
