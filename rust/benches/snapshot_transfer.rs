//! Bench/driver for **§8.1** — the snapshot-transfer test (insert 10 000
//! vectors, snapshot → H_A, restore → H_B, verify H_A ≡ H_B and identical
//! k-NN ordering), plus snapshot/restore/hash throughput.
//!
//! Run: `cargo bench --bench snapshot_transfer`

use valori::bench::{bench, BenchConfig, Report};
use valori::experiments::{synthetic_embeddings, transfer};
use valori::snapshot::Snapshot;
use valori::state::{Command, Kernel, KernelConfig};

fn main() {
    let quick = std::env::var("VALORI_BENCH_QUICK").is_ok();
    let n = if quick { 1000 } else { 10_000 };

    // The paper's protocol.
    let r = transfer::run(n, 128);
    transfer::print_result(&r);
    assert!(r.hashes_equal && r.knn_identical, "determinism violation!");

    // Throughput of the snapshot machinery at a few scales.
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    for scale in [1000usize, 5000] {
        let embeddings = synthetic_embeddings(scale, 128, 32, 7);
        let mut kernel = Kernel::new(KernelConfig::default_q16(128));
        for (id, v) in embeddings.iter().enumerate() {
            kernel.apply(Command::insert(id as u64, v.clone())).unwrap();
        }
        let snap = Snapshot::capture(&kernel);
        let bytes = snap.to_bytes();
        let mut report =
            Report::new(format!("snapshot machinery, {scale} × dim-128 ({} MiB)", bytes.len() >> 20));
        report.add("capture (encode+fnv+sha)", bench(&cfg, || Snapshot::capture(&kernel)));
        report.add("state_hash only (fnv)", bench(&cfg, || kernel.state_hash()));
        report.add(
            "restore (parse+verify+rebuild)",
            bench(&cfg, || Snapshot::from_bytes(&bytes).unwrap().restore().unwrap()),
        );
        report.note(format!("snapshot size: {} bytes", bytes.len()));
        report.print();
    }
}
