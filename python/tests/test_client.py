"""Integration: the Python FFI/HTTP client against a real `valori serve`
process (Figure 1's Python interface layer, end to end).

Skipped when the release binary has not been built yet.
"""

import os
import signal
import socket
import subprocess
import sys
import time

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from valori_client import ValoriClient, ValoriError, replicate  # noqa: E402

BIN = os.path.join(os.path.dirname(__file__), "..", "..", "target", "release", "valori")


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.fixture(scope="module")
def node():
    if not os.path.exists(BIN):
        pytest.skip("release binary not built (cargo build --release)")
    port = free_port()
    proc = subprocess.Popen(
        [BIN, "serve", "--addr", f"127.0.0.1:{port}", "--dim", "4", "--no-embedder"],
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )
    client = ValoriClient(f"http://127.0.0.1:{port}")
    for _ in range(100):
        if client.health():
            break
        time.sleep(0.05)
    else:
        proc.kill()
        pytest.skip("node did not come up")
    yield client
    proc.send_signal(signal.SIGKILL)
    proc.wait()


def test_insert_query_roundtrip(node):
    node.insert(1, vector=[0.1, 0.2, 0.3, 0.4])
    node.insert(2, vector=[0.9, 0.8, 0.7, 0.6])
    hits = node.query(vector=[0.1, 0.2, 0.3, 0.4], k=2)
    assert hits[0]["id"] == 1
    assert hits[0]["dist_raw"] == 0


def test_batch_link_meta_delete(node):
    node.insert_batch([(10, [0.5, 0, 0, 0]), (11, [0, 0.5, 0, 0])])
    node.link(10, 11)
    node.set_meta(10, "source", "pytest")
    stats = node.stats()
    assert stats["vectors"] >= 4
    node.delete(11)
    with pytest.raises(ValoriError) as e:
        node.delete(11)
    assert e.value.status == 404


def test_duplicate_id_is_conflict(node):
    with pytest.raises(ValoriError) as e:
        node.insert(1, vector=[0, 0, 0, 0])
    assert e.value.status == 409


def test_state_hash_shape(node):
    h = node.state_hash()
    assert len(h["fnv"]) == 16
    assert len(h["sha256"]) == 64
    assert h["seq"] > 0


def test_log_feed_and_python_side_replication(node):
    if not os.path.exists(BIN):
        pytest.skip("no binary")
    # spin a follower and replicate from python (the §9 protocol)
    port = free_port()
    proc = subprocess.Popen(
        [BIN, "serve", "--addr", f"127.0.0.1:{port}", "--dim", "4", "--no-embedder"],
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )
    try:
        follower = ValoriClient(f"http://127.0.0.1:{port}")
        for _ in range(100):
            if follower.health():
                break
            time.sleep(0.05)
        follower_hash = replicate(node, follower)
        assert follower_hash == node.state_hash()["fnv"]
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait()


def test_text_endpoints_without_embedder(node):
    with pytest.raises(ValoriError) as e:
        node.query(text="anything", k=3)
    assert e.value.status == 503
