"""Layer-2: the MiniLM-shaped JAX embedding encoder.

Substitute for `sentence-transformers/all-MiniLM-L6-v2` (DESIGN §2): a
4-layer post-LN transformer encoder, d_model=128, 4 heads, FFN 512,
vocab 4096, seq 64, masked-mean pooling + L2 normalization. Weights are
deterministic (PRNGKey(0)) and are *runtime parameters* of the lowered HLO,
exported separately as little-endian binaries — exactly how a served model
ships, and it keeps the HLO text small.

Two lowerings of the same mathematics simulate the paper's two machines
(Table 1, §2.1 mechanism — reduction order / fused-kernel differences):

* env A — attention through the Pallas fused kernel; plain f32 evaluation
  (one rounding per operation).
* env B — attention through the pure-jnp reference path; the encoder is
  evaluated with extended-precision (f64) intermediates and rounded to f32
  once at the end — precisely the FMA/extended-precision mechanism of
  paper §2.1 ("a×b+c can be computed with a single rounding step (FMA) or
  two; these yield slightly different results"), as an x87/FMA/TF32-style
  backend legally does. The divergence compounds through layers exactly
  like it does across real ISAs. (A pure *reordering* difference is not
  enough here: XLA CPU's default fast-math reassociates f32 reductions,
  folding both orders into the same code — itself a tidy demonstration of
  how compilers legally change float results.)

Both are IEEE-754-legal evaluations of the same function; their outputs
differ at the bit level on the same host, which is the root cause the
paper demonstrates across x86 vs ARM.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ref as kref

# Architecture constants — shared with the Rust runtime via the weight
# manifest written by aot.py.
VOCAB = 4096
D_MODEL = 128
N_HEADS = 4
D_HEAD = D_MODEL // N_HEADS
N_LAYERS = 4
D_FF = 512
SEQ_LEN = 64
BATCH = 8

PAD_ID = 0  # token id 0 is reserved for padding


class Weights(NamedTuple):
    """Stacked per-layer weights (leading axis = layer) + embeddings."""

    tok_emb: jax.Array   # [VOCAB, D_MODEL]
    pos_emb: jax.Array   # [SEQ_LEN, D_MODEL]
    ln1_g: jax.Array     # [L, D]
    ln1_b: jax.Array     # [L, D]
    wqkv: jax.Array      # [L, D, 3D]
    bqkv: jax.Array      # [L, 3D]
    wo: jax.Array        # [L, D, D]
    bo: jax.Array        # [L, D]
    ln2_g: jax.Array     # [L, D]
    ln2_b: jax.Array     # [L, D]
    w1: jax.Array        # [L, D, F]
    b1: jax.Array        # [L, F]
    w2: jax.Array        # [L, F, D]
    b2: jax.Array        # [L, D]
    lnf_g: jax.Array     # [D]
    lnf_b: jax.Array     # [D]


def init_weights(seed: int = 0) -> Weights:
    """Deterministic Xavier-ish init from a fixed PRNG key."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 16)
    L, D, F = N_LAYERS, D_MODEL, D_FF

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in)))

    # Residual-branch outputs (wo, w2) are scaled down (µP-style small
    # init) so the residual stream stays dominated by the token-identity
    # signal: an *untrained* encoder then still maps texts with shared
    # vocabulary near each other (bag-of-words-like), which the corpus
    # retrieval experiments need, while keeping real transformer compute
    # in the pipeline.
    return Weights(
        tok_emb=dense(ks[0], (VOCAB, D), D) * 4.0,  # wider spread for token identity
        pos_emb=dense(ks[1], (SEQ_LEN, D), D) * 0.3,
        ln1_g=jnp.ones((L, D), jnp.float32),
        ln1_b=jnp.zeros((L, D), jnp.float32),
        wqkv=dense(ks[2], (L, D, 3 * D), D),
        bqkv=jnp.zeros((L, 3 * D), jnp.float32),
        wo=dense(ks[3], (L, D, D), D) * 0.1,
        bo=jnp.zeros((L, D), jnp.float32),
        ln2_g=jnp.ones((L, D), jnp.float32),
        ln2_b=jnp.zeros((L, D), jnp.float32),
        w1=dense(ks[4], (L, D, F), D),
        b1=jnp.zeros((L, F), jnp.float32),
        w2=dense(ks[5], (L, F, D), F) * 0.1,
        b2=jnp.zeros((L, D), jnp.float32),
        lnf_g=jnp.ones((D,), jnp.float32),
        lnf_b=jnp.zeros((D,), jnp.float32),
    )


def _attention_env_a(q, k, v, bias):
    """env A: the Pallas fused kernel (interpret mode on CPU)."""
    return attn_kernel.attention(q, k, v, bias)


def _attention_env_b(q, k, v, bias):
    """env B: mathematically identical pure-jnp path (different fusion /
    reduction structure after XLA lowering)."""
    return kref.attention_ref(q, k, v, bias)


def encoder(w: Weights, token_ids, env: str = "a"):
    """Embed a batch of token sequences.

    Args:
      w: model weights.
      token_ids: int32[B, S]; id 0 = padding.
      env: "a" or "b" — which evaluation environment to simulate.

    Returns:
      f32[B, D_MODEL], L2-normalized embeddings.
    """
    assert env in ("a", "b")
    attn_fn = _attention_env_a if env == "a" else _attention_env_b
    b, s = token_ids.shape

    mask = (token_ids != PAD_ID).astype(jnp.float32)            # [B, S]
    bias = (1.0 - mask) * jnp.float32(-1e9)                      # additive key bias

    x = w.tok_emb[token_ids] + w.pos_emb[None, :s, :]            # [B, S, D]

    # env B evaluates the encoder with extended-precision intermediates
    # (f64) and rounds to f32 once at the end — the legal IEEE-754
    # evaluation an FMA/x87/TF32-style backend produces (paper §2.1: one
    # rounding vs two). The divergence then compounds through every layer,
    # as it does across real ISAs. env A is plain f32 throughout.
    if env == "b":
        x = x.astype(jnp.float64)
        bias = bias.astype(jnp.float64)

    for layer in range(N_LAYERS):
        h = kref.layernorm_ref(x, w.ln1_g[layer], w.ln1_b[layer])
        qkv = h @ w.wqkv[layer] + w.bqkv[layer]                  # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, N_HEADS, D_HEAD).transpose(0, 2, 1, 3)

        o = attn_fn(heads(q), heads(k), heads(v), bias)          # [B, H, S, Dh]
        o = o.transpose(0, 2, 1, 3).reshape(b, s, D_MODEL)
        x = x + o @ w.wo[layer] + w.bo[layer]

        h2 = kref.layernorm_ref(x, w.ln2_g[layer], w.ln2_b[layer])
        ff = jax.nn.gelu(h2 @ w.w1[layer] + w.b1[layer])
        x = x + ff @ w.w2[layer] + w.b2[layer]

    x = kref.layernorm_ref(x, w.lnf_g, w.lnf_b)                  # [B, S, D]

    # Masked mean pooling. env A: f32 accumulation (a rounding per step).
    # env B: f64 intermediate accumulation, rounded once at the end — the
    # FMA/extended-precision mechanism of paper §2.1. Mathematically the
    # same mean; bitwise different.
    xm = x * mask[:, :, None].astype(x.dtype)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0).astype(x.dtype)
    pooled = jnp.sum(xm, axis=1)
    norm_sq = jnp.sum(pooled * pooled, axis=-1, keepdims=True)
    pooled = pooled / denom
    norm = jnp.sqrt(norm_sq) / denom
    out = pooled / jnp.maximum(norm, 1e-9)
    # single final rounding for env B (f64 -> f32)
    return out.astype(jnp.float32)


def embed_fn(env: str):
    """The function aot.py lowers: (weights..., token_ids) -> (embeddings,)."""

    def fn(*args):
        w = Weights(*args[:-1])
        token_ids = args[-1]
        return (encoder(w, token_ids, env=env),)

    return fn


@functools.lru_cache(maxsize=2)
def jitted_encoder(env: str):
    return jax.jit(functools.partial(encoder, env=env), static_argnames=())
