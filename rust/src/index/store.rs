//! Dense vector storage shared by the index implementations.
//!
//! External ids (u64, chosen by clients) are mapped to dense internal slots
//! (u32). Slots are never reused — deletion is a tombstone — so internal
//! ids are a pure function of insertion order, which the state machine
//! makes deterministic (paper §7.1 "fixed ordering"). The id map is a
//! `BTreeMap` (sorted iteration) so serialization order is canonical.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::Scalar;
use std::collections::BTreeMap;

/// Append-only vector store with tombstones.
///
/// Storage is a single contiguous arena: slot `i` occupies
/// `data[i*dim .. (i+1)*dim]`. One allocation instead of one per vector
/// means the flat-search hot path streams linearly through cache and the
/// blocked distance kernels (`distance::dot_q16_block` et al.) can score
/// whole runs of slots per call. The on-disk encoding is unchanged from
/// the per-slot layout (see [`VecStore::encode`]) — the arena is purely an
/// in-memory representation, so snapshot bytes and hashes are identical.
#[derive(Debug, Clone, PartialEq)]
pub struct VecStore<S: Scalar> {
    dim: usize,
    /// Contiguous vector arena; slot `i` at `[i*dim, (i+1)*dim)`.
    data: Vec<S>,
    /// Slot -> external id.
    external_ids: Vec<u64>,
    /// Slot -> live?
    alive: Vec<bool>,
    /// External id -> slot.
    id_to_slot: BTreeMap<u64, u32>,
    live_count: usize,
}

impl<S: Scalar> VecStore<S> {
    pub fn new(dim: usize) -> Self {
        Self {
            dim,
            data: Vec::new(),
            external_ids: Vec::new(),
            alive: Vec::new(),
            id_to_slot: BTreeMap::new(),
            live_count: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total slots ever allocated (including tombstones).
    pub fn slots(&self) -> usize {
        self.external_ids.len()
    }

    /// The whole contiguous arena (`slots() * dim` scalars, tombstones
    /// included). Batch scoring reads this directly.
    pub fn arena(&self) -> &[S] {
        &self.data
    }

    /// Slot-indexed liveness flags (parallel to the arena rows).
    pub fn alive_flags(&self) -> &[bool] {
        &self.alive
    }

    /// Slot-indexed external ids (parallel to the arena rows).
    pub fn external_ids(&self) -> &[u64] {
        &self.external_ids
    }

    pub fn live_len(&self) -> usize {
        self.live_count
    }

    pub fn contains(&self, id: u64) -> bool {
        self.slot_of(id).is_some()
    }

    /// Whether this external id was ever inserted (live OR tombstoned).
    /// Ids are never reusable — replay invariance depends on it.
    pub fn ever_contains(&self, id: u64) -> bool {
        self.id_to_slot.contains_key(&id)
    }

    /// Slot of a *live* external id.
    pub fn slot_of(&self, id: u64) -> Option<u32> {
        self.id_to_slot.get(&id).copied().filter(|&s| self.alive[s as usize])
    }

    /// Slot of an external id, live or tombstoned. Membership proofs cover
    /// deleted records too (the tombstone leaf, see [`crate::proof`]).
    pub fn any_slot_of(&self, id: u64) -> Option<u32> {
        self.id_to_slot.get(&id).copied()
    }

    pub fn external_id(&self, slot: u32) -> u64 {
        self.external_ids[slot as usize]
    }

    pub fn is_alive(&self, slot: u32) -> bool {
        self.alive[slot as usize]
    }

    pub fn vec_at(&self, slot: u32) -> &[S] {
        let start = slot as usize * self.dim;
        &self.data[start..start + self.dim]
    }

    pub fn get(&self, id: u64) -> Option<&[S]> {
        self.slot_of(id).map(|s| self.vec_at(s))
    }

    /// Insert under a fresh external id, returning the new slot.
    /// Panics if the id already maps to a slot (state machine pre-checks)
    /// or the dimension is wrong.
    pub fn insert(&mut self, id: u64, vector: Vec<S>) -> u32 {
        assert_eq!(vector.len(), self.dim, "dimension mismatch");
        assert!(
            !self.id_to_slot.contains_key(&id),
            "duplicate external id {id} (state machine must pre-check)"
        );
        let slot = self.external_ids.len() as u32;
        self.data.extend_from_slice(&vector);
        self.external_ids.push(id);
        self.alive.push(true);
        self.id_to_slot.insert(id, slot);
        self.live_count += 1;
        slot
    }

    /// Tombstone. Returns the slot if the id was live.
    pub fn delete(&mut self, id: u64) -> Option<u32> {
        let slot = self.slot_of(id)?;
        self.alive[slot as usize] = false;
        self.live_count -= 1;
        Some(slot)
    }

    /// In-place divergence repair (see [`crate::proof`]): overwrite one
    /// slot's vector and/or liveness without touching slot numbering or
    /// the id map. `vector = None` keeps the arena row's current bytes
    /// (tombstone repair — the leaf encoding carries no vector).
    pub fn overwrite_slot(&mut self, slot: u32, vector: Option<&[S]>, alive: bool) {
        let s = slot as usize;
        assert!(s < self.external_ids.len(), "slot out of range");
        if let Some(v) = vector {
            assert_eq!(v.len(), self.dim, "dimension mismatch");
            let start = s * self.dim;
            self.data[start..start + self.dim].copy_from_slice(v);
        }
        if self.alive[s] != alive {
            if alive {
                self.live_count += 1;
            } else {
                self.live_count -= 1;
            }
            self.alive[s] = alive;
        }
    }

    /// Iterate live (slot, external id, vector) in slot (= insertion) order.
    pub fn iter_live(&self) -> impl Iterator<Item = (u32, u64, &[S])> {
        (0..self.external_ids.len() as u32).filter_map(move |s| {
            if self.alive[s as usize] {
                Some((s, self.external_ids[s as usize], self.vec_at(s)))
            } else {
                None
            }
        })
    }

    /// Canonical serialization (slot order; tombstones preserved so slot
    /// numbering — and thus the HNSW graph — survives a round-trip).
    /// Byte-identical to the historical per-slot layout: each slot still
    /// writes `id ‖ alive ‖ len(=dim) ‖ scalars`, the arena is invisible
    /// on the wire.
    pub fn encode(&self, e: &mut Encoder) {
        e.put_u32(self.dim as u32);
        e.put_u32(self.external_ids.len() as u32);
        for s in 0..self.external_ids.len() {
            e.put_u64(self.external_ids[s]);
            e.put_u8(self.alive[s] as u8);
            e.put_u32(self.dim as u32);
            for &x in self.vec_at(s as u32) {
                x.encode(e);
            }
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let dim = d.get_u32()? as usize;
        let n = d.get_u32()? as usize;
        // No up-front reserve from the (untrusted) header counts: a
        // corrupt stream claiming huge n*dim must fall out as a clean
        // DecodeError when the input runs dry, not a capacity panic or a
        // giant allocation. Amortized growth is fine off the hot path.
        let mut store = Self::new(dim);
        for slot in 0..n {
            let id = d.get_u64()?;
            let alive = match d.get_u8()? {
                0 => false,
                1 => true,
                t => return Err(DecodeError::InvalidTag { what: "alive flag", tag: t as u64 }),
            };
            let len = d.get_u32()? as usize;
            if len != dim {
                return Err(DecodeError::InvalidTag { what: "vector dim", tag: len as u64 });
            }
            for _ in 0..len {
                store.data.push(S::decode(d)?);
            }
            store.external_ids.push(id);
            store.alive.push(alive);
            store.id_to_slot.insert(id, slot as u32);
            if alive {
                store.live_count += 1;
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> VecStore<i32> {
        let mut s = VecStore::new(2);
        s.insert(10, vec![1, 2]);
        s.insert(20, vec![3, 4]);
        s.insert(5, vec![5, 6]);
        s
    }

    #[test]
    fn insert_assigns_slots_in_order() {
        let s = sample();
        assert_eq!(s.slot_of(10), Some(0));
        assert_eq!(s.slot_of(20), Some(1));
        assert_eq!(s.slot_of(5), Some(2));
        assert_eq!(s.live_len(), 3);
    }

    #[test]
    fn delete_tombstones_without_slot_reuse() {
        let mut s = sample();
        assert_eq!(s.delete(20), Some(1));
        assert_eq!(s.delete(20), None); // double delete
        assert_eq!(s.live_len(), 2);
        assert_eq!(s.slots(), 3);
        assert!(!s.is_alive(1));
        assert_eq!(s.get(20), None);
        // new insert gets a fresh slot
        s.insert(99, vec![7, 8]);
        assert_eq!(s.slot_of(99), Some(3));
    }

    #[test]
    #[should_panic(expected = "duplicate external id")]
    fn duplicate_id_panics() {
        let mut s = sample();
        s.insert(10, vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn wrong_dim_panics() {
        let mut s = sample();
        s.insert(11, vec![0, 0, 0]);
    }

    #[test]
    fn iter_live_is_slot_ordered() {
        let mut s = sample();
        s.delete(20);
        let ids: Vec<u64> = s.iter_live().map(|(_, id, _)| id).collect();
        assert_eq!(ids, vec![10, 5]);
    }

    #[test]
    fn overwrite_slot_repairs_in_place() {
        let mut s = sample();
        s.overwrite_slot(1, Some(&[9, 9]), true);
        assert_eq!(s.get(20), Some(&[9, 9][..]));
        assert_eq!(s.live_len(), 3);
        // tombstone repair keeps the arena bytes but kills the slot
        s.overwrite_slot(1, None, false);
        assert_eq!(s.get(20), None);
        assert_eq!(s.any_slot_of(20), Some(1));
        assert_eq!(s.vec_at(1), &[9, 9]);
        assert_eq!(s.live_len(), 2);
        // resurrect (repairing a wrongly-deleted record)
        s.overwrite_slot(1, Some(&[3, 4]), true);
        assert_eq!(s.get(20), Some(&[3, 4][..]));
        assert_eq!(s.live_len(), 3);
        // idempotent liveness
        s.overwrite_slot(1, None, true);
        assert_eq!(s.live_len(), 3);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut s = sample();
        s.delete(20);
        let mut e = Encoder::new();
        s.encode(&mut e);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        let s2 = VecStore::<i32>::decode(&mut d).unwrap();
        d.finish().unwrap();
        assert_eq!(s, s2);
        // and the re-encoding is byte-identical (canonical form)
        let mut e2 = Encoder::new();
        s2.encode(&mut e2);
        assert_eq!(bytes, e2.into_vec());
    }

    #[test]
    fn arena_is_contiguous_and_row_aligned() {
        let mut s = sample();
        s.delete(20);
        s.insert(99, vec![7, 8]);
        assert_eq!(s.arena().len(), s.slots() * s.dim());
        for slot in 0..s.slots() as u32 {
            let start = slot as usize * s.dim();
            assert_eq!(s.vec_at(slot), &s.arena()[start..start + s.dim()]);
        }
        assert_eq!(s.external_ids(), &[10, 20, 5, 99]);
        assert_eq!(s.alive_flags(), &[true, false, true, true]);
    }

    #[test]
    fn f32_store_roundtrip_bitexact() {
        let mut s: VecStore<f32> = VecStore::new(2);
        s.insert(1, vec![0.1, -0.0]);
        let mut e = Encoder::new();
        s.encode(&mut e);
        let bytes = e.into_vec();
        let s2 = VecStore::<f32>::decode(&mut Decoder::new(&bytes)).unwrap();
        assert_eq!(s2.get(1).unwrap()[1].to_bits(), (-0.0f32).to_bits());
    }
}
