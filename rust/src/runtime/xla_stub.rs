//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The reproduction builds in hermetic environments with no network and no
//! PJRT shared library. This module mirrors the small slice of the
//! `xla` crate API that [`super::engine`] and [`super::embedder`] consume,
//! so the whole crate compiles and tests run everywhere:
//!
//! - [`Literal`] is a *real* implementation (host-side typed buffers with
//!   shape), so literal construction/readback helpers work and are tested.
//! - Client/compile/execute paths return a descriptive [`Error`]: callers
//!   already guard every execution path behind
//!   [`super::artifacts_available`] or propagate `Engine::cpu()` failures,
//!   so the node degrades to vector-only serving exactly as it does when
//!   `make artifacts` has not been run.
//!
//! Linking the real PJRT client back in is a build-system concern: swap the
//! `use super::xla_stub as xla;` lines in `engine.rs`/`embedder.rs` for the
//! real crate. Nothing else in the tree touches PJRT types.

#![forbid(unsafe_code)]

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display only; the runtime layer
/// stringifies immediately).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA runtime is not linked into this build (offline stub); \
         float-model endpoints are disabled"
    ))
}

/// Typed host buffer element. Sealed to the three dtypes the AOT artifacts
/// use.
pub trait NativeType: Copy + fmt::Debug {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

/// Storage for [`Literal`] (public only because [`NativeType`] mentions it).
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
        }
    }
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I64(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side literal: typed data + shape. Fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { data: T::wrap(data.to_vec()), dims }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read back as a host vector of `T` (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error(format!("to_vec: dtype mismatch ({:?})", self.data)))
    }

    /// First element of a result tuple. The stub never produces tuples, so
    /// this is the identity (mirrors `return_tuple=True` unwrapping).
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Ok(self)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self, Error> {
        Err(unavailable(&format!("parse HLO {:?}", path.as_ref())))
    }
}

/// An XLA computation (opaque).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in practice: `HloModuleProto::from_text_file` is the
        // only constructor and it always errors in the stub.
        XlaComputation(())
    }
}

/// Device buffer handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn client_is_unavailable_offline() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }
}
