//! Quickstart: the Valori kernel in 60 lines.
//!
//! Demonstrates the core loop — insert vectors through the quantization
//! boundary, search, link memories, snapshot, restore, and verify the
//! state hash is preserved bit-for-bit.
//!
//! Run: `cargo run --release --example quickstart`

use valori::snapshot::Snapshot;
use valori::state::{Command, Kernel, KernelConfig};

fn main() {
    // A 4-dimensional Q16.16 kernel with the default HNSW index.
    let mut kernel = Kernel::new(KernelConfig::default_q16(4));

    // Insert float vectors: they are validated and quantized to Q16.16 at
    // the boundary; everything after that is integer math.
    kernel.apply(Command::insert(1, vec![0.10, 0.20, 0.30, 0.40])).unwrap();
    kernel.apply(Command::insert(2, vec![0.90, 0.80, 0.70, 0.60])).unwrap();
    kernel.apply(Command::insert(3, vec![0.11, 0.19, 0.31, 0.39])).unwrap();

    // Link related memories and attach metadata — all part of the same
    // deterministic state machine.
    kernel.apply(Command::Link { from: 3, to: 1 }).unwrap();
    kernel
        .apply(Command::SetMeta { id: 1, key: "source".into(), value: "quickstart".into() })
        .unwrap();

    // k-NN search. Distances are exact integers (shown dequantized).
    let hits = kernel.search_f32(&[0.1, 0.2, 0.3, 0.4], 3).unwrap();
    println!("query [0.1, 0.2, 0.3, 0.4]:");
    for h in &hits {
        println!("  id {}  dist {:.6}  (raw Q32.32: {})", h.id, h.dist, h.dist_raw);
    }
    assert_eq!(hits[0].id, 1);

    // The state hash: any machine replaying these commands gets this hash.
    let h = kernel.state_hash();
    println!("state hash = {h:016x}");

    // Snapshot -> bytes -> restore: bit-identical state (paper §8.1).
    let snap = Snapshot::capture(&kernel);
    let restored = Snapshot::from_bytes(&snap.to_bytes()).unwrap().restore().unwrap();
    assert_eq!(restored.state_hash(), h);
    assert_eq!(restored.search_f32(&[0.1, 0.2, 0.3, 0.4], 3).unwrap(), hits);
    println!("snapshot -> restore preserved the state exactly ({} bytes)", snap.to_bytes().len());

    // Deleting and re-querying is deterministic too.
    kernel.apply(Command::Delete { id: 1 }).unwrap();
    let hits = kernel.search_f32(&[0.1, 0.2, 0.3, 0.4], 3).unwrap();
    println!("after delete(1), nearest = id {}", hits[0].id);
    assert_eq!(hits[0].id, 3);

    println!("quickstart OK");
}
