//! Deterministic SQ8 scalar quantization for the flat scan tier.
//!
//! At millions of vectors the Q16.16 arena is the flat query path's
//! bandwidth ceiling: 4 bytes per component, full-width scan per query.
//! This module compresses each Q16.16 component to an i8 *code* so the
//! candidate-selection scan reads 4× fewer bytes (4× more components per
//! cache line) and runs on narrow integer SIMD; the final ranking is then
//! decided by the exact Q16.16 kernels over only `k * overscan`
//! candidates (see `FlatIndex::search`).
//!
//! ## Integer-only encode, derived from fixed corpus bounds
//!
//! The boundary contract (`vector::ValidationPolicy`, default
//! `max_abs = 4.0`) guarantees every admitted Q16.16 component satisfies
//! `|raw| ≤ 4.0 * 2^16 = 2^18`. That bound is a *config constant*, not a
//! data statistic, so the per-dimension scale derived from it is the same
//! for every dimension and — crucially — independent of the corpus
//! contents: inserting or deleting vectors can never invalidate
//! previously computed codes, and two replicas that applied the same
//! commands hold bit-identical code arenas without ever exchanging them.
//!
//! The encode is pure integer arithmetic (no floats anywhere):
//!
//! ```text
//! code(raw) = clamp(round_half_away_from_zero(raw * 127 / 2^18), -127, 127)
//! ```
//!
//! computed in i64 (|raw * 127| ≤ 2^25, no overflow). Rounding half away
//! from zero keeps the map odd (`code(-raw) = -code(raw)`), so quantized
//! L2/IP geometry has no sign bias. The code −128 is never produced,
//! which keeps the difference range symmetric in the kernels below.
//!
//! ## Exactness of the accumulators
//!
//! With codes in [-127, 127] and the kernel dim contract (dim ≤ 16384,
//! enforced at the state boundary):
//!
//! - squared-L2 term ≤ 254² = 64516, sum ≤ 64516 · 16384 < 2^31 − 1;
//! - |dot| term ≤ 127² = 16129, |sum| ≤ 16129 · 16384 < 2^29.
//!
//! So plain wrapping `+` on an i32 accumulator is exact — the same
//! argument (and the same auto-vectorization payoff) as the Q16.16
//! kernels in [`crate::distance`], one word narrower.
//!
//! ## Why the final top-k stays deterministic
//!
//! Codes are a pure per-component function of the vector, the approx scan
//! ranks candidates under the total order `(approx_dist, id)`, and the
//! exact re-rank ranks the surviving candidates under the existing
//! `(dist_raw, id)` order — three pure functions composed, no clocks, no
//! floats, no data-dependent scales. See `PERFORMANCE.md` §8 for the
//! full exactness/recall argument.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};

/// Fixed per-component bound on Q16.16 raw values, from the boundary
/// contract `max_abs = 4.0` (`4.0 * 2^16`). A config constant — never a
/// corpus statistic — so codes are insert-order- and content-independent.
pub const QUANT_BOUND_RAW: i32 = 1 << 18;

/// Default candidate overscan for SQ8 two-phase search: the approx scan
/// keeps `k * overscan` candidates for the exact re-rank.
pub const SQ8_DEFAULT_OVERSCAN: u32 = 4;

/// Per-collection quantization spec (part of `KernelConfig`; rides in
/// `spec.json` and the `/v2` PUT body as `"quant": "none" | "sq8"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantSpec {
    /// No quantized tier: every query is a full-width exact scan.
    None,
    /// Scalar-quantize to i8 codes; two-phase search with exact re-rank
    /// over `k * overscan` candidates.
    Sq8 { overscan: u32 },
}

impl QuantSpec {
    pub fn sq8_default() -> Self {
        QuantSpec::Sq8 { overscan: SQ8_DEFAULT_OVERSCAN }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantSpec::None => "none",
            QuantSpec::Sq8 { .. } => "sq8",
        }
    }

    /// Stable on-disk tag (STATE_VERSION 3 config field).
    pub fn tag(&self) -> u8 {
        match self {
            QuantSpec::None => 0,
            QuantSpec::Sq8 { .. } => 1,
        }
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.put_u8(self.tag());
        if let QuantSpec::Sq8 { overscan } = self {
            e.put_u32(*overscan);
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        match d.get_u8()? {
            0 => Ok(QuantSpec::None),
            1 => {
                let overscan = d.get_u32()?;
                if overscan == 0 {
                    return Err(DecodeError::InvalidTag { what: "sq8 overscan", tag: 0 });
                }
                Ok(QuantSpec::Sq8 { overscan })
            }
            t => Err(DecodeError::InvalidTag { what: "quant spec", tag: t as u64 }),
        }
    }
}

/// Deterministic Q16.16 → i8 scalar quantizer. Stateless apart from the
/// dimension it validates against: the scale is the fixed boundary-bound
/// constant for every dimension (see module docs), so encoding is a pure
/// per-component function.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Quantizer {
    dim: usize,
}

impl Quantizer {
    pub fn new(dim: usize) -> Self {
        Self { dim }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Encode one Q16.16 raw component to its i8 code — pure integer
    /// arithmetic, round half away from zero, clamped to [-127, 127].
    #[inline]
    pub fn encode_component(raw: i32) -> i8 {
        let num = raw as i64 * 127;
        let den = QUANT_BOUND_RAW as i64;
        // Truncating division after biasing by den/2 toward the sign of
        // the numerator = round half away from zero (den/2 = 2^17 exact).
        let rounded = if num >= 0 { (num + den / 2) / den } else { (num - den / 2) / den };
        rounded.clamp(-127, 127) as i8
    }

    /// Append the codes for one vector to a code arena. The vector must
    /// match the quantizer's dimension (same contract as `VecStore`).
    pub fn encode_append(&self, raw: &[i32], codes: &mut Vec<i8>) {
        debug_assert_eq!(raw.len(), self.dim, "quantizer dimension mismatch");
        codes.extend(raw.iter().map(|&r| Self::encode_component(r)));
    }

    /// Encode a query vector to its i8 codes, or `None` when the scalar
    /// type does not expose Q16.16 raws (`Scalar::as_q16_raw`). Pure per
    /// component, so every caller — the sequential two-phase search and
    /// each parallel sub-range scan task — derives identical codes from
    /// the same query.
    pub fn encode_query<S: Scalar>(query: &[S]) -> Option<Vec<i8>> {
        let mut codes = Vec::with_capacity(query.len());
        for &x in query {
            codes.push(Self::encode_component(x.as_q16_raw()?));
        }
        Some(codes)
    }
}

/// Quantized squared-L2 over i8 codes, exact i32 accumulation (overflow
/// argument in the module docs). Same reslice idiom as
/// [`crate::distance::l2sq_q16`] so LLVM drops the inner bounds checks
/// and auto-vectorizes.
#[inline]
pub fn sq8_l2(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len(), "sq8_l2: equal-length contract violated");
    let b = &b[..a.len()];
    let mut acc: i32 = 0;
    for i in 0..a.len() {
        let d = a[i] as i32 - b[i] as i32;
        acc += d * d;
    }
    acc
}

/// Quantized dot product over i8 codes (same contract as [`sq8_l2`]).
#[inline]
pub fn sq8_dot(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len(), "sq8_dot: equal-length contract violated");
    let b = &b[..a.len()];
    let mut acc: i32 = 0;
    for i in 0..a.len() {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// Approximate distance under `metric` in code space (smaller = closer,
/// mirroring the exact kernels: IP/Cosine negate the dot).
#[inline]
pub fn sq8_distance(metric: Metric, a: &[i8], b: &[i8]) -> i32 {
    match metric {
        Metric::L2 => sq8_l2(a, b),
        Metric::InnerProduct | Metric::Cosine => sq8_dot(a, b).saturating_neg(),
    }
}

/// Blocked variant: score `query` against `out.len()` code rows stored
/// back-to-back in `block` (row `r` at `block[r*dim..(r+1)*dim]`). Exact
/// per row, so bit-identical to per-row [`sq8_distance`] calls — the
/// batch form only changes the access pattern, like the Q16.16 block
/// kernels. `dim` must be non-zero.
#[inline]
pub fn sq8_distance_block(metric: Metric, query: &[i8], block: &[i8], dim: usize, out: &mut [i32]) {
    debug_assert!(dim > 0, "sq8_distance_block: dim must be non-zero");
    debug_assert_eq!(query.len(), dim, "sq8_distance_block: query/dim mismatch");
    debug_assert_eq!(block.len(), dim * out.len(), "sq8_distance_block: block shape mismatch");
    for (row, d) in block.chunks_exact(dim).zip(out.iter_mut()) {
        *d = sq8_distance(metric, query, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_is_odd_and_clamped() {
        for raw in [0, 1, 2048, 2049, 123_456, QUANT_BOUND_RAW, i32::MAX] {
            assert_eq!(
                Quantizer::encode_component(raw),
                -Quantizer::encode_component(raw.saturating_neg()),
                "odd symmetry at raw={raw}"
            );
        }
        assert_eq!(Quantizer::encode_component(0), 0);
        assert_eq!(Quantizer::encode_component(QUANT_BOUND_RAW), 127);
        assert_eq!(Quantizer::encode_component(-QUANT_BOUND_RAW), -127);
        // Out-of-contract values still clamp instead of wrapping.
        assert_eq!(Quantizer::encode_component(i32::MAX), 127);
        assert_eq!(Quantizer::encode_component(i32::MIN), -127);
        // -128 is never produced.
        for raw in (-(1 << 19)..(1 << 19)).step_by(997) {
            assert!(Quantizer::encode_component(raw) >= -127);
        }
    }

    #[test]
    fn encode_rounds_half_away_from_zero() {
        // One code step is 2^18/127 raw units; the half-step boundary for
        // code 1 is at num = den/2, i.e. raw = 2^17/127 rounded up.
        let den = QUANT_BOUND_RAW as i64;
        for code in 1..=126i64 {
            // Smallest raw whose scaled value reaches code - 0.5.
            let boundary = ((2 * code - 1) * den + 2 * 127 - 1) / (2 * 127);
            let raw = boundary as i32;
            assert_eq!(Quantizer::encode_component(raw), code as i8, "at boundary for {code}");
            assert_eq!(Quantizer::encode_component(raw - 1), (code - 1) as i8);
            assert_eq!(Quantizer::encode_component(-raw), -(code as i8));
        }
    }

    #[test]
    fn kernels_match_wide_reference() {
        // Independent i64 reference over a pseudo-random code corpus.
        let gen = |seed: i64, n: usize| -> Vec<i8> {
            (0..n)
                .map(|i| (((seed + i as i64) * 2654435761 % 255) - 127).clamp(-127, 127) as i8)
                .collect()
        };
        let a = gen(1, 300);
        let b = gen(7, 300);
        let l2_ref: i64 =
            a.iter().zip(&b).map(|(&x, &y)| ((x as i64) - (y as i64)).pow(2)).sum();
        let dot_ref: i64 = a.iter().zip(&b).map(|(&x, &y)| (x as i64) * (y as i64)).sum();
        assert_eq!(sq8_l2(&a, &b) as i64, l2_ref);
        assert_eq!(sq8_dot(&a, &b) as i64, dot_ref);
        assert_eq!(sq8_distance(Metric::InnerProduct, &a, &b) as i64, -dot_ref);
        assert_eq!(
            sq8_distance(Metric::Cosine, &a, &b),
            sq8_distance(Metric::InnerProduct, &a, &b)
        );
    }

    #[test]
    fn accumulator_extremes_do_not_overflow() {
        // Worst case under the dim contract: 16384 components at the
        // extreme codes. 254^2 * 16384 = 1_057_030_144 < i32::MAX.
        let a = vec![127i8; 16384];
        let b = vec![-127i8; 16384];
        assert_eq!(sq8_l2(&a, &b), 254 * 254 * 16384);
        assert_eq!(sq8_dot(&a, &b), -127 * 127 * 16384);
    }

    #[test]
    fn block_kernel_matches_per_row() {
        let dim = 5;
        let rows = 11;
        let q: Vec<i8> = (0..dim).map(|i| (i as i8 * 17).wrapping_sub(40)).collect();
        let block: Vec<i8> = (0..dim * rows).map(|i| ((i * 31 % 200) as i8)).collect();
        for metric in [Metric::L2, Metric::InnerProduct] {
            let mut out = vec![0i32; rows];
            sq8_distance_block(metric, &q, &block, dim, &mut out);
            for r in 0..rows {
                let row = &block[r * dim..(r + 1) * dim];
                assert_eq!(out[r], sq8_distance(metric, &q, row), "row {r}");
            }
        }
    }

    #[test]
    fn spec_roundtrip_and_tags() {
        for spec in [QuantSpec::None, QuantSpec::Sq8 { overscan: 4 }, QuantSpec::Sq8 { overscan: 100 }] {
            let mut e = Encoder::new();
            spec.encode(&mut e);
            let bytes = e.into_vec();
            let mut d = Decoder::new(&bytes);
            assert_eq!(QuantSpec::decode(&mut d).unwrap(), spec);
            d.finish().unwrap();
        }
        // zero overscan is rejected on decode
        let mut e = Encoder::new();
        e.put_u8(1);
        e.put_u32(0);
        let bytes = e.into_vec();
        assert!(QuantSpec::decode(&mut Decoder::new(&bytes)).is_err());
        assert_eq!(QuantSpec::None.name(), "none");
        assert_eq!(QuantSpec::sq8_default().name(), "sq8");
    }

    #[test]
    fn quantizer_append_encodes_rows() {
        let qz = Quantizer::new(3);
        let mut codes = Vec::new();
        qz.encode_append(&[0, QUANT_BOUND_RAW, -(QUANT_BOUND_RAW / 2)], &mut codes);
        qz.encode_append(&[1 << 16, -(1 << 16), 0], &mut codes);
        assert_eq!(codes.len(), 6);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[1], 127);
        assert_eq!(codes[2], -64); // -2^17 * 127 / 2^18 = -63.5 → away from zero
        assert_eq!(codes[3], Quantizer::encode_component(1 << 16));
    }
}
